//! Continuous-batching serving engine: the scheduler may change *when*
//! sessions advance, never *what* they emit.
//!
//! The acceptance bar for the iteration-level scheduler (ISSUE 8):
//! per-session token streams under the continuous scheduler are
//! bit-identical to sequential `InferenceSession::generate` across
//! shards=1/2/4 x adapter kinds (base/LoRA/IA3/prefix) with staggered
//! arrivals; session churn under tenant quotas surfaces typed
//! `AdmissionDenied` on the request handle and provably releases the
//! KV ledger charge, tenant quota, and decode slot on retirement;
//! background sessions yield their slot (and quota) to foreground
//! arrivals; and a shard killed mid-iteration recovers
//! token-identically behind the walk's bounded retry.
//!
//! Tests skip when artifacts are absent (same convention as
//! `integration.rs`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             FaultAction, FaultPlan, FaultRule,
                             GenerationConfig, HandleStatus, Placement,
                             RetryPolicy, ServingRequest,
                             SymbiosisError, TenantQuota};
use symbiosis::runtime::Engine;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

/// One engine (compile cache) shared by every deployment in this file.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(&artifact_dir()).unwrap()))
        .clone()
}

fn deploy(shards: usize) -> Deployment {
    let placement = if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  BatchPolicy::Continuous, placement)
        .unwrap()
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i * 7 + 3) as i32 % 256).collect()
}

fn lora8() -> Adapter {
    Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                 LoraTargets::QKVO, 2.0)
        .unwrap()
}

fn adapter_kinds() -> Vec<(&'static str, Option<Adapter>)> {
    vec![
        ("base", None),
        ("lora8", Some(lora8())),
        ("ia3", Some(Adapter::ia3(&SYM_TINY))),
        ("prefix4", Some(Adapter::prefix(&SYM_TINY, 1, 4, 11))),
    ]
}

/// Sequential golden for one spec on an existing deployment.
fn sequential(dep: &Deployment, adapter: &Option<Adapter>,
              toks: &[i32], cfg: &GenerationConfig) -> Vec<Vec<i32>> {
    let mut b = dep.session();
    if let Some(a) = adapter {
        b = b.adapter(a.clone());
    }
    let mut sess = b.build().unwrap();
    sess.generate(toks, cfg).unwrap()
}

/// Tentpole acceptance: staggered arrivals across every adapter kind,
/// driven concurrently by the iteration-level scheduler with fewer
/// slots than sessions (so retirement must refill slots mid-run), emit
/// token streams bit-identical to sequential `generate` — at every
/// shard count.
#[test]
fn continuous_scheduler_matches_sequential_across_shards_and_adapters() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for shards in [1usize, 2, 4] {
        let dep = deploy(shards);
        let kinds = adapter_kinds();
        // two requests per adapter kind with different prompt/output
        // lengths — mixed enough that iterations interleave prefill
        // chunks and decodes of different sessions
        let specs: Vec<(&str, usize, Vec<i32>, GenerationConfig)> =
            (0..2 * kinds.len())
                .map(|i| {
                    let k = i % kinds.len();
                    let toks = prompt(8 + 4 * (i / kinds.len()));
                    let cfg = GenerationConfig::greedy(6 + 2 * (i % 3));
                    (kinds[k].0, k, toks, cfg)
                })
                .collect();
        let goldens: Vec<Vec<Vec<i32>>> = specs
            .iter()
            .map(|(_, k, toks, cfg)| {
                sequential(&dep, &kinds[*k].1, toks, cfg)
            })
            .collect();

        // fewer slots than sessions + staggered submission: early
        // sessions are deep into decode when late ones prefill
        let mut srv = dep
            .serving()
            .slots(3)
            .admit_per_step(2)
            .prefill_chunk(4)
            .build();
        let mut handles = Vec::new();
        for (i, (_, k, toks, cfg)) in specs.iter().enumerate() {
            let mut req =
                ServingRequest::new(toks.clone(), cfg.clone());
            if let Some(a) = &kinds[*k].1 {
                req = req.adapter(a.clone());
            }
            handles.push(srv.submit(req));
            if i % 2 == 1 {
                // interleave arrivals with live iterations
                srv.step().unwrap();
            }
        }
        let report = srv.run().unwrap();
        assert_eq!(report.completed as usize, specs.len(),
                   "shards={shards}: every session must finish");
        assert_eq!(report.failed, 0);
        for (i, h) in handles.iter().enumerate() {
            assert_eq!(h.status(), HandleStatus::Finished,
                       "shards={shards} session {i}");
            assert_eq!(h.tokens(), goldens[i],
                       "shards={shards} {} session {i}: scheduler \
                        stream diverged from sequential generate",
                       specs[i].0);
        }
        dep.shutdown();
    }
}

/// Handles stream incrementally: `poll` returns only tokens emitted
/// since the last `poll`, and the concatenation equals the final
/// stream.
#[test]
fn handle_poll_streams_incrementally() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(1);
    let cfg = GenerationConfig::greedy(8);
    let golden = sequential(&dep, &None, &prompt(8), &cfg);
    let mut srv = dep.serving().slots(1).build();
    let h = srv.submit(ServingRequest::new(prompt(8), cfg));
    let mut streamed: Vec<i32> = Vec::new();
    while !h.is_done() {
        srv.step().unwrap();
        streamed.extend(h.poll()[0].iter());
    }
    assert_eq!(h.status(), HandleStatus::Finished);
    assert!(h.poll()[0].is_empty(), "poll cursor must not rewind");
    assert_eq!(vec![streamed], golden);
    assert_eq!(h.tokens(), golden, "tokens() must not move the cursor");
    dep.shutdown();
}

/// Churn storm under a tenant session quota: over-subscribed arrivals
/// surface typed `AdmissionDenied` on their handles while in-quota
/// sessions proceed; once those finish, the *same tenant* admits again
/// (tickets released on retirement), and after the storm the tenant
/// count, decode slots, and KV ledger are all provably back to zero.
#[test]
fn churn_storm_respects_tenant_quota_with_typed_denials() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    dep.admission()
        .set_quota("acme", TenantQuota::unlimited().max_sessions(2));
    let cfg = GenerationConfig::greedy(6);
    let golden = sequential(&dep, &None, &prompt(8), &cfg);
    assert_eq!(dep.client_device.lock().unwrap().ledger.used(), 0,
               "sequential golden session must have released its KV");

    let mut srv = dep
        .serving()
        .slots(4)
        .admit_per_step(8)
        .prefill_chunk(4)
        .build();
    let handles: Vec<_> = (0..6)
        .map(|_| {
            srv.submit(ServingRequest::new(prompt(8), cfg.clone())
                .tenant("acme"))
        })
        .collect();
    srv.run().unwrap();
    // first two queued requests fit the quota; the rest are denied with
    // the typed error naming the tenant
    for (i, h) in handles.iter().enumerate() {
        if i < 2 {
            assert_eq!(h.status(), HandleStatus::Finished,
                       "in-quota session {i}");
            assert_eq!(h.tokens(), golden);
        } else {
            assert_eq!(h.status(), HandleStatus::Denied,
                       "over-quota session {i}");
            match h.take_error() {
                Some(SymbiosisError::AdmissionDenied {
                    tenant, ..
                }) => assert_eq!(tenant, "acme"),
                other => panic!(
                    "expected typed AdmissionDenied, got {other:?}"),
            }
        }
    }
    // steady state after the storm: the tenant's tickets were released
    // on retirement, so fresh submissions admit again
    let h = srv.submit(
        ServingRequest::new(prompt(8), cfg.clone()).tenant("acme"));
    srv.run().unwrap();
    assert_eq!(h.status(), HandleStatus::Finished);
    assert_eq!(h.tokens(), golden);

    assert_eq!(srv.active(), 0, "slots must drain after the storm");
    assert_eq!(dep.admission().tenant("acme").sessions(), 0,
               "tenant session tickets leaked");
    assert_eq!(dep.client_device.lock().unwrap().ledger.used(), 0,
               "KV ledger charge leaked");
    dep.shutdown();
}

/// Under pressure a background session yields: a foreground arrival
/// with no free slot evicts it (typed terminal state, partial stream a
/// prefix of its sequential run) and — because eviction releases the
/// tenant ticket — the foreground request admits under the same
/// 1-session quota in the same scheduler step.
#[test]
fn background_session_yields_slot_quota_and_kv_to_foreground() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(1);
    dep.admission()
        .set_quota("solo", TenantQuota::unlimited().max_sessions(1));
    let long = GenerationConfig::greedy(64);
    let short = GenerationConfig::greedy(6);
    let golden_long = sequential(&dep, &None, &prompt(8), &long);
    let golden_short = sequential(&dep, &None, &prompt(8), &short);

    let mut srv = dep.serving().slots(1).prefill_chunk(4).build();
    let bg = srv.submit(ServingRequest::new(prompt(8), long)
        .background()
        .tenant("solo"));
    for _ in 0..6 {
        srv.step().unwrap();
    }
    assert_eq!(bg.status(), HandleStatus::Decoding,
               "background session should be mid-decode");
    let fg = srv.submit(
        ServingRequest::new(prompt(8), short).tenant("solo"));
    srv.run().unwrap();

    assert_eq!(bg.status(), HandleStatus::Evicted);
    let bg_tokens = bg.tokens();
    assert!(!bg_tokens[0].is_empty() && bg_tokens[0].len() < 64,
            "evicted mid-stream, got {} tokens", bg_tokens[0].len());
    assert!(golden_long[0].starts_with(&bg_tokens[0]),
            "evicted stream must be a prefix of the sequential run");
    assert_eq!(fg.status(), HandleStatus::Finished,
               "foreground must admit under the freed quota");
    assert_eq!(fg.tokens(), golden_short);

    assert_eq!(srv.active(), 0);
    assert_eq!(dep.admission().tenant("solo").sessions(), 0,
               "eviction must release the tenant ticket");
    assert_eq!(dep.client_device.lock().unwrap().ledger.used(), 0,
               "eviction must release the KV ledger charge");
    dep.shutdown();
}

/// Chaos cell: a shard killed mid-iteration (fault-injected on the
/// serving sessions' own routes) recovers token-identically — the
/// walk's bounded retry rides across the watchdog respawn, and no
/// session fails or diverges.
#[test]
fn shard_killed_mid_iteration_recovers_token_identically() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let kinds = adapter_kinds();
    let cfg = GenerationConfig::greedy(8);
    // goldens ride clean routes: computed before faults are armed
    let goldens: Vec<Vec<Vec<i32>>> = kinds
        .iter()
        .map(|(_, a)| sequential(&dep, a, &prompt(12), &cfg))
        .collect();

    // every serving session is built after this, so each one's route to
    // shard 1 kills it once, a few requests into the walk — mid
    // iteration by construction
    dep.inject_faults(FaultPlan::new(29).rule(
        FaultRule::on(1, FaultAction::KillShard).from_step(5).times(1),
    ));
    let mut srv = dep
        .serving()
        .slots(4)
        .prefill_chunk(4)
        .request_timeout(Duration::from_millis(250))
        .retry(RetryPolicy::retries(8)
            .with_backoff(Duration::from_millis(10)))
        .build();
    let handles: Vec<_> = kinds
        .iter()
        .map(|(_, a)| {
            let mut req =
                ServingRequest::new(prompt(12), cfg.clone());
            if let Some(a) = a {
                req = req.adapter(a.clone());
            }
            srv.submit(req)
        })
        .collect();
    let report = srv.run().unwrap();
    assert_eq!(report.failed, 0,
               "retry must absorb the mid-iteration kill");
    for (i, h) in handles.iter().enumerate() {
        assert_eq!(h.status(), HandleStatus::Finished,
                   "{} session", kinds[i].0);
        assert_eq!(h.tokens(), goldens[i],
                   "{}: post-respawn stream diverged", kinds[i].0);
    }
    assert!(dep.executor.respawns() >= 1,
            "the kill never actually landed");
    dep.clear_faults();
    dep.shutdown();
}

/// Scheduler surface sanity that needs no artifacts: terminal-status
/// classification and the report's human-readable rendering.
#[test]
fn handle_status_terminality_and_report_render() {
    use symbiosis::coordinator::ServingReport;
    for s in [HandleStatus::Finished, HandleStatus::Denied,
              HandleStatus::Evicted, HandleStatus::Failed] {
        assert!(s.is_terminal());
    }
    for s in [HandleStatus::Queued, HandleStatus::Prefilling,
              HandleStatus::Decoding] {
        assert!(!s.is_terminal());
    }
    let r = ServingReport::default();
    let text = format!("{r}");
    assert!(text.contains("submitted"), "{text}");
    assert!(text.contains("ttft"), "{text}");
    assert!(text.contains("itl"), "{text}");
}
