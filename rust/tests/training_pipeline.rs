//! Pipelined multi-adapter fine-tuning: the GPipe wavefront must change
//! *when* micro-batches run, never *what* the step computes — and
//! training memory must be a first-class ledger citizen.
//!
//! The acceptance bar (ISSUE 10): micro-batched gradient accumulation is
//! bit-identical to the full-batch sequential walk (loss trajectory AND
//! adapter parameters after K steps) across shard counts and
//! micro-batch counts; inference-only adapters stay typed-NotTrainable;
//! the capacity edge fires typed QuotaExceeded/TrainerOom with both
//! books (tenant, device ledger) rolled back cleanly and co-tenants
//! unaffected; `client_state_bytes` reports the live ledger balance;
//! and the fleet's training counters track the wavefront.
//!
//! Tests skip when artifacts are absent (same convention as
//! `integration.rs`).

use std::path::PathBuf;
use std::sync::Arc;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::admission::TenantQuota;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             Placement, SymbiosisError, Trainer};
use symbiosis::runtime::Engine;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

/// One engine (compile cache) shared by every deployment in this file.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(&artifact_dir()).unwrap()))
        .clone()
}

fn deploy(shards: usize) -> Deployment {
    let placement = if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  BatchPolicy::NoLockstep, placement)
        .unwrap()
}

fn lora8() -> Adapter {
    Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                 LoraTargets::QKVO, 2.0)
        .unwrap()
}

fn data(batch: usize) -> (Vec<i32>, Vec<i32>) {
    let t = batch * 16;
    ((0..t).map(|i| ((i * 7 + 3) % 256) as i32).collect(),
     (0..t).map(|i| ((i * 5 + 2) % 256) as i32).collect())
}

/// K train steps; returns (loss bits per step, adapter param bits).
fn run_steps(tr: &mut Trainer, batch: usize, steps: usize)
             -> (Vec<u32>, Vec<u32>) {
    let (tokens, labels) = data(batch);
    let losses: Vec<u32> = (0..steps)
        .map(|_| tr.train_step(&tokens, &labels).unwrap().loss.to_bits())
        .collect();
    let params: Vec<u32> = tr.core.adapter.as_ref().unwrap()
        .flatten()
        .iter()
        .map(|p| p.to_bits())
        .collect();
    (losses, params)
}

/// The tentpole equivalence: micro-batched accumulation over the
/// wavefront is bit-identical to the full-batch sequential walk —
/// loss trajectory AND adapter parameters after K steps — at every
/// shards x micro-batches point.
#[test]
fn pipelined_training_is_bit_identical_to_sequential() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = |shards: usize, micro: usize| {
        let dep = deploy(shards);
        let mut tr = dep.trainer()
            .adapter(lora8())
            .batch(4)
            .micro_batches(micro)
            .lr(5e-3)
            .build()
            .unwrap();
        let out = run_steps(&mut tr, 4, 3);
        drop(tr);
        dep.shutdown();
        out
    };
    let golden = run(1, 1);
    assert!(golden.0.windows(2).any(|w| w[1] != w[0]),
            "degenerate loss trajectory");
    for shards in [1usize, 2, 4] {
        for micro in [1usize, 2, 4] {
            if shards == 1 && micro == 1 {
                continue;
            }
            let got = run(shards, micro);
            assert_eq!(got.0, golden.0,
                       "loss bits diverged at shards={shards} \
                        micro={micro}");
            assert_eq!(got.1, golden.1,
                       "adapter params diverged at shards={shards} \
                        micro={micro}");
        }
    }
}

/// Micro-batching unlocks batches the sequential walk cannot run at
/// all (8 is not an attention batch size) — and the trajectory stays
/// bit-identical across shard counts.
#[test]
fn micro_batching_unlocks_batch_eight() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Sequential batch 8 is a typed UnsupportedBatch…
    let dep = deploy(1);
    match dep.trainer().adapter(lora8()).batch(8).build() {
        Err(SymbiosisError::UnsupportedBatch { batch, .. }) => {
            assert_eq!(batch, 8)
        }
        other => panic!("expected UnsupportedBatch, got {other:?}"),
    }
    dep.shutdown();
    // …but 8x1 micro-batches run, identically on every fleet size.
    let run = |shards: usize| {
        let dep = deploy(shards);
        let mut tr = dep.trainer()
            .adapter(lora8())
            .batch(8)
            .micro_batches(8)
            .lr(5e-3)
            .build()
            .unwrap();
        let out = run_steps(&mut tr, 8, 2);
        drop(tr);
        dep.shutdown();
        out
    };
    let golden = run(1);
    for shards in [2usize, 4] {
        assert_eq!(run(shards), golden,
                   "batch-8 training diverged at shards={shards}");
    }
}

/// Invalid micro-batch splits and inference-only adapters fail typed
/// at build, micro-batched or not.
#[test]
fn invalid_splits_and_adapters_fail_typed() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(1);
    // batch not divisible by micro_batches
    match dep.trainer().adapter(lora8()).batch(4).micro_batches(3)
        .build()
    {
        Err(SymbiosisError::InvalidMicroBatch {
            batch, micro_batches, ..
        }) => {
            assert_eq!((batch, micro_batches), (4, 3));
        }
        other => panic!("expected InvalidMicroBatch, got {other:?}"),
    }
    // per-micro-batch size not an attention batch size (16/2 = 8)
    match dep.trainer().adapter(lora8()).batch(16).micro_batches(2)
        .build()
    {
        Err(SymbiosisError::InvalidMicroBatch { batch, .. }) => {
            assert_eq!(batch, 16);
        }
        other => panic!("expected InvalidMicroBatch, got {other:?}"),
    }
    // IA3 and Prefix stay inference-only under the pipelined path too
    match dep.trainer().adapter(Adapter::ia3(&SYM_TINY)).batch(2)
        .micro_batches(2).build()
    {
        Err(SymbiosisError::NotTrainable { .. }) => {}
        other => panic!("expected NotTrainable, got {other:?}"),
    }
    match dep.trainer().adapter(Adapter::prefix(&SYM_TINY, 1, 4, 11))
        .batch(2).micro_batches(2).build()
    {
        Err(SymbiosisError::NotTrainable { .. }) => {}
        other => panic!("expected NotTrainable, got {other:?}"),
    }
    dep.shutdown();
}

/// The capacity edge, tenant book first: trainers admit until the
/// tenant's training-bytes quota fires QuotaExceeded — with the failed
/// build leaving both books exactly where they were, and the admitted
/// co-tenant still able to train (mirrors the KV OOM test shape).
#[test]
fn tenant_quota_edge_rolls_back_both_books() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let probe = dep.trainer().adapter(lora8()).batch(1).build().unwrap();
    let opt_bytes = probe.optimizer.state_bytes();
    drop(probe);
    dep.executor.admission().set_quota(
        "edge",
        TenantQuota::unlimited().max_train_bytes(opt_bytes * 3 / 2));
    let mut first = dep.trainer().adapter(lora8()).batch(1)
        .tenant("edge").build().unwrap();
    let tenant = dep.executor.admission().tenant("edge");
    assert_eq!(tenant.train_bytes(), opt_bytes);
    let used_before = {
        let d = dep.client_device.lock().unwrap();
        d.ledger.used()
    };
    // The second trainer busts the tenant quota: typed QuotaExceeded,
    // tenant book unchanged, device ledger unchanged.
    match dep.trainer().adapter(lora8()).batch(1).tenant("edge").build()
    {
        Err(SymbiosisError::QuotaExceeded { .. }) => {}
        other => panic!("expected QuotaExceeded, got {other:?}"),
    }
    assert_eq!(tenant.train_bytes(), opt_bytes,
               "failed admit leaked tenant training bytes");
    {
        let d = dep.client_device.lock().unwrap();
        assert_eq!(d.ledger.used(), used_before,
                   "failed admit leaked device ledger bytes");
    }
    // The admitted co-tenant is unaffected: it keeps training.
    let (tokens, labels) = data(1);
    first.train_step(&tokens, &labels).unwrap();
    // Trainer exit returns its balance on both books.
    drop(first);
    assert_eq!(tenant.train_bytes(), 0);
    {
        let d = dep.client_device.lock().unwrap();
        assert_eq!(d.ledger.used(), used_before - opt_bytes);
    }
    dep.shutdown();
}

/// The device-ledger edge: when the client device cannot hold another
/// trainer's Adam state, the build fails with typed TrainerOom naming
/// the charge — and an activation-stash OOM mid-step rolls the act
/// book back to zero so the trainer can retry after the quota loosens.
#[test]
fn trainer_oom_fires_at_device_edge_and_step_rolls_back() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let probe = dep.trainer().adapter(lora8()).batch(1).build().unwrap();
    let opt_bytes = probe.optimizer.state_bytes();
    drop(probe);
    // Fill the client device so the next Adam state cannot fit.
    {
        let mut d = dep.client_device.lock().unwrap();
        let free = d.ledger.capacity() - d.ledger.used();
        d.ledger.set("test:filler", free - opt_bytes / 2).unwrap();
    }
    match dep.trainer().adapter(lora8()).batch(1).build() {
        Err(SymbiosisError::TrainerOom { what, need_bytes, .. }) => {
            assert_eq!(what, "optimizer state");
            assert_eq!(need_bytes, opt_bytes);
        }
        other => panic!("expected TrainerOom, got {other:?}"),
    }
    {
        let mut d = dep.client_device.lock().unwrap();
        d.ledger.free("test:filler");
    }
    // Mid-step act OOM: quota admits the Adam state but not the
    // activation stash.  The step fails typed and the act book rolls
    // back to zero — loosening the quota makes the SAME trainer step.
    dep.executor.admission().set_quota(
        "burst",
        TenantQuota::unlimited().max_train_bytes(opt_bytes + 64));
    let mut tr = dep.trainer().adapter(lora8()).batch(2)
        .micro_batches(2).tenant("burst").build().unwrap();
    let tenant = dep.executor.admission().tenant("burst");
    let (tokens, labels) = data(2);
    match tr.train_step(&tokens, &labels) {
        Err(SymbiosisError::QuotaExceeded { .. }) => {}
        other => panic!("expected QuotaExceeded mid-step, \
                         got {other:?}"),
    }
    assert_eq!(tenant.train_bytes(), opt_bytes,
               "failed step leaked activation-stash bytes");
    assert_eq!(tr.client_state_bytes(16),
               tr.core.adapter.as_ref().unwrap().n_params() as u64 * 4
                   + opt_bytes,
               "act tag must be zero after the rollback");
    dep.executor.admission()
        .set_quota("burst", TenantQuota::unlimited());
    tr.train_step(&tokens, &labels).unwrap();
    drop(tr);
    dep.shutdown();
}

/// Satellite: `client_state_bytes` reports the live ledger balance
/// once the trainer is ledger-attached — report == books, by
/// construction.
#[test]
fn client_state_bytes_reports_the_ledger_balance() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(1);
    let used0 = {
        let d = dep.client_device.lock().unwrap();
        d.ledger.used()
    };
    let mut tr = dep.trainer().adapter(lora8()).batch(2)
        .micro_batches(2).lr(5e-3).build().unwrap();
    let adapter_bytes =
        tr.core.adapter.as_ref().unwrap().n_params() as u64 * 4;
    // Between steps the act tag is drained: balance = adapter + Adam.
    let expect = adapter_bytes + tr.optimizer.state_bytes();
    assert_eq!(tr.client_state_bytes(16), expect);
    {
        let d = dep.client_device.lock().unwrap();
        assert_eq!(d.ledger.used() - used0,
                   tr.optimizer.state_bytes(),
                   "ledger must carry exactly the Adam state");
    }
    let (tokens, labels) = data(2);
    tr.train_step(&tokens, &labels).unwrap();
    // Stash charges drained back to zero when backward consumed them.
    assert_eq!(tr.client_state_bytes(16), expect);
    // The stash DID get charged while the step ran: peak > resting.
    {
        let d = dep.client_device.lock().unwrap();
        assert!(d.ledger.peak() > used0 + tr.optimizer.state_bytes(),
                "activation stash never hit the ledger");
    }
    drop(tr);
    dep.shutdown();
}

/// Satellite: the fleet's training counters track the wavefront —
/// grad-accum steps, peak micro-batches in flight, peak stash bytes —
/// and surface in the FleetStats display.
#[test]
fn fleet_stats_track_the_training_wavefront() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let mut tr = dep.trainer()
        .adapter(lora8())
        .batch(4)
        .micro_batches(4)
        .lr(5e-3)
        .build()
        .unwrap();
    let (tokens, labels) = data(4);
    tr.train_step(&tokens, &labels).unwrap();
    tr.train_step(&tokens, &labels).unwrap();
    assert_eq!(dep.train_stats.microbatches_in_flight(), 0,
               "wavefront drained");
    drop(tr);
    let stats = dep.shutdown();
    assert_eq!(stats.train_grad_accum_steps, 8,
               "2 steps x 4 micro-batches");
    assert_eq!(stats.train_microbatches_in_flight_peak, 4,
               "all micro-batches fill the pipeline together");
    assert!(stats.train_activation_stash_peak_bytes > 0);
    let shown = format!("{stats}");
    assert!(shown.contains("training: 8 grad accum step(s)"),
            "display missing training line:\n{shown}");
}
