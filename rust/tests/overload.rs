//! Overload suite: the fleet's behavior at and past its admission
//! limits (ISSUE 7).
//!
//! The acceptance bar: a 64-session flood against low tenant quotas
//! yields *only typed errors* — `AdmissionDenied` / `QuotaExceeded` /
//! `ShardSaturated` / `WorkShed` — never a deadlock or a panic;
//! interactive requests make token-identical progress while background
//! work browns out; a failing shard trips its circuit breaker so
//! clients fast-fail (`ShardUnavailable { retries: 0 }`) instead of
//! burning their retry budgets against it.  Every cell runs under a
//! hard watchdog deadline, like the chaos suite.
//!
//! Route-level cells (flood against an echo shard, the breaker
//! state-machine property test) run everywhere; deployment-level cells
//! skip when artifacts are absent (same convention as `chaos.rs`).

use std::path::PathBuf;
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::fleet::WATCHDOG_INTERVAL;
use symbiosis::coordinator::proto::{LayerRequest, LayerResponse,
                                    OpKind, SHED_MARKER};
use symbiosis::coordinator::proto::ExecMsg;
use symbiosis::coordinator::{AdmissionController, BatchPolicy,
                             BreakerState, CircuitBreaker, Deployment,
                             FaultAction, FaultPlan, FaultRule,
                             GenerationConfig, IngressMeter,
                             LayerAssignment, LayerId, Placement,
                             RetryPolicy, RoutingTable, ShardEndpoint,
                             ShardRoute, SymbiosisError, TenantQuota,
                             Urgency, VirtLayerCtx};
use symbiosis::runtime::Engine;
use symbiosis::tensor::Tensor;
use symbiosis::transport::LinkKind;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

/// One engine (compile cache) shared by every deployment in this file.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(&artifact_dir()).unwrap()))
        .clone()
}

fn deploy(shards: usize) -> Deployment {
    let placement = if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  BatchPolicy::NoLockstep, placement)
        .unwrap()
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i * 7 + 3) as i32 % 256).collect()
}

/// Same seed convention as the chaos suite: `CHAOS_SEED` pins one.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("CHAOS_SEED") {
        Ok(s) => vec![s
            .trim()
            .parse()
            .expect("CHAOS_SEED must be an unsigned integer")],
        Err(_) => vec![7, 1337, 987654321],
    }
}

/// Run `f` on its own thread under a hard deadline: a cell that
/// deadlocks fails the suite instead of hanging it.
fn with_deadline<T: Send + 'static>(
    what: &str, limit: Duration,
    f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = channel();
    let handle = std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(limit) {
        Ok(v) => {
            let _ = handle.join();
            v
        }
        Err(RecvTimeoutError::Disconnected) => match handle.join() {
            Err(panic) => std::panic::resume_unwind(panic),
            Ok(()) => unreachable!("sender dropped without panicking"),
        },
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: no result within {limit:?} — deadlocked");
        }
    }
}

const CHAOS_TIMEOUT: Duration = Duration::from_millis(250);

/// The same mixer `RetryPolicy` jitter uses; local copy so the test
/// does not depend on a crate-private helper.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Minimal shard stand-in for route-level cells: dequeues, releases
/// the ingress slot exactly the way a real executor's run loop does,
/// holds the request for `service` (so a flood can out-run it and back
/// the queue up), then echoes the activation back.
fn echo_shard(meter: Arc<IngressMeter>, service: Duration)
              -> Sender<ExecMsg> {
    let (tx, rx) = channel();
    std::thread::spawn(move || {
        while let Ok(msg) = rx.recv() {
            if let ExecMsg::Request(req) = msg {
                meter.exit();
                if !service.is_zero() {
                    std::thread::sleep(service);
                }
                let _ = req.resp.send(LayerResponse {
                    y: Ok(req.x.clone()),
                    queue_wait_secs: 0.0,
                    batch_clients: 1,
                });
            }
        }
    });
    tx
}

// ------------------------------------------------------------------
// Route-level overload: runs without artifacts.
// ------------------------------------------------------------------

/// Tentpole acceptance, route level: 64 clients flooding one slow
/// shard through a bounded ingress queue fail only in typed ways —
/// `ShardSaturated` backpressure for the untenanted half,
/// `QuotaExceeded` for the half sharing a tight tenant budget — while
/// some work still completes.  No deadlock, no panic, no untyped
/// error.
#[test]
fn dispatch_flood_yields_only_typed_overload_errors() {
    let (ok, saturated, quota) = with_deadline(
        "64-client dispatch flood", Duration::from_secs(120), || {
        let meter = Arc::new(IngressMeter::with_high_water(4));
        let breaker = Arc::new(CircuitBreaker::disabled());
        let tx = echo_shard(meter.clone(), Duration::from_millis(1));
        let endpoint = Arc::new(ShardEndpoint::with_shared(
            tx, meter, breaker));
        let admission = AdmissionController::new();
        admission.set_quota(
            "flood", TenantQuota::unlimited().max_in_flight(2));
        let tenant = admission.tenant("flood");

        let barrier = Arc::new(Barrier::new(64));
        let handles: Vec<_> = (0..64)
            .map(|client| {
                let endpoint = endpoint.clone();
                let tenant = tenant.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let routing = RoutingTable::new(
                        LayerAssignment::contiguous(SYM_TINY.n_layers,
                                                    1),
                        vec![ShardRoute::shared(0, endpoint,
                                                LinkKind::SharedLocal)],
                    )
                    .unwrap();
                    let mut ctx = VirtLayerCtx::new(client, routing);
                    ctx.request_timeout = Some(Duration::from_secs(10));
                    // Even clients share the tight tenant budget (the
                    // quota gate keeps them off the queue); odd ones
                    // are untenanted and can saturate the ingress
                    // high-water mark.
                    if client % 2 == 0 {
                        ctx.tenant = Some(tenant);
                    }
                    barrier.wait();
                    let (mut ok, mut sat, mut quota) = (0u32, 0u32, 0u32);
                    for _ in 0..8 {
                        match ctx.forward(LayerId::Qkv(0),
                                          Tensor::zeros(&[1, 4]),
                                          Urgency::Bulk) {
                            Ok(_) => ok += 1,
                            Err(e) => match e
                                .downcast_ref::<SymbiosisError>()
                            {
                                Some(SymbiosisError::ShardSaturated {
                                    ..
                                }) => sat += 1,
                                Some(SymbiosisError::QuotaExceeded {
                                    ..
                                }) => quota += 1,
                                _ => panic!(
                                    "flood produced an untyped or \
                                     unexpected error: {e:#}"),
                            },
                        }
                    }
                    (ok, sat, quota)
                })
            })
            .collect();
        let mut totals = (0u32, 0u32, 0u32);
        for h in handles {
            let (ok, sat, quota) =
                h.join().expect("flood thread panicked");
            totals.0 += ok;
            totals.1 += sat;
            totals.2 += quota;
        }
        totals
    });
    assert!(ok >= 1, "the flood starved every client: 0 successes");
    assert!(saturated >= 1,
            "32 untenanted clients × 8 dispatches never pushed a \
             1ms-service shard past high-water 4 (ok={ok})");
    assert!(quota >= 1,
            "32 clients sharing max_in_flight=2 never collided with \
             the quota (ok={ok})");
}

/// Satellite (c): the circuit breaker's transition graph, checked
/// against an explicit reference model under seeded random event
/// streams (failure / success / probe / allow / reset).  State,
/// admission decisions, and the lifetime transition counter must all
/// match the model after every event.
#[test]
fn breaker_transitions_match_reference_model() {
    #[derive(Debug)]
    struct Model {
        state: BreakerState,
        run: u32,
        probe_inflight: bool,
        threshold: u32,
        transitions: u64,
    }
    impl Model {
        fn close(&mut self) {
            self.run = 0;
            self.probe_inflight = false;
            if self.state != BreakerState::Closed {
                self.transitions += 1;
            }
            self.state = BreakerState::Closed;
        }
        fn allow(&mut self) -> bool {
            match self.state {
                BreakerState::Closed => true,
                BreakerState::Open => false,
                BreakerState::HalfOpen => {
                    if self.probe_inflight {
                        false
                    } else {
                        self.probe_inflight = true;
                        true
                    }
                }
            }
        }
        fn failure(&mut self) {
            self.run = self.run.saturating_add(1);
            match self.state {
                BreakerState::HalfOpen => {
                    self.probe_inflight = false;
                    self.state = BreakerState::Open;
                    self.transitions += 1;
                }
                BreakerState::Closed if self.run >= self.threshold => {
                    self.state = BreakerState::Open;
                    self.transitions += 1;
                }
                _ => {}
            }
        }
        fn probe(&mut self) {
            if self.state == BreakerState::Open {
                self.state = BreakerState::HalfOpen;
                self.probe_inflight = false;
                self.transitions += 1;
            } else if self.state == BreakerState::HalfOpen {
                self.probe_inflight = false;
            }
        }
    }

    for seed in chaos_seeds() {
        let threshold = 1 + (seed % 4) as u32;
        let breaker = CircuitBreaker::with_threshold(threshold);
        let mut model = Model {
            state: BreakerState::Closed,
            run: 0,
            probe_inflight: false,
            threshold,
            transitions: 0,
        };
        let mut rng = seed;
        for step in 0..4096u32 {
            let r = splitmix64(&mut rng) % 16;
            match r {
                0..=5 => {
                    breaker.record_failure();
                    model.failure();
                }
                6..=9 => {
                    breaker.record_success();
                    model.close();
                }
                10..=12 => {
                    assert_eq!(breaker.allow(), model.allow(),
                               "seed {seed} step {step}: admission \
                                diverged in {:?}", model);
                }
                13..=14 => {
                    breaker.probe();
                    model.probe();
                }
                _ => {
                    breaker.reset();
                    model.close();
                }
            }
            assert_eq!(breaker.state(), model.state,
                       "seed {seed} step {step}: state diverged \
                        (event {r}) in {:?}", model);
            assert_eq!(breaker.transitions(), model.transitions,
                       "seed {seed} step {step}: transition count \
                        diverged in {:?}", model);
        }
        assert!(model.transitions > 0,
                "seed {seed}: the event stream never tripped the \
                 breaker — property test exercised nothing");
    }
}

/// A disabled breaker (threshold 0, the default) is inert: it never
/// leaves `Closed` and never refuses a dispatch, whatever happens.
#[test]
fn disabled_breaker_never_trips() {
    let breaker = CircuitBreaker::disabled();
    let mut rng = 42u64;
    for _ in 0..512 {
        match splitmix64(&mut rng) % 3 {
            0 => breaker.record_failure(),
            1 => breaker.probe(),
            _ => assert!(breaker.allow()),
        }
        assert_eq!(breaker.state(), BreakerState::Closed);
    }
    assert_eq!(breaker.transitions(), 0);
}

// ------------------------------------------------------------------
// Deployment-level overload: skips when artifacts are absent.
// ------------------------------------------------------------------

/// Tentpole acceptance, deployment level: 64 concurrent sessions
/// against a tenant quota of 6 produce only typed outcomes — a
/// successful generation, `AdmissionDenied` at build, or one of the
/// overload family mid-run — and the whole flood resolves under a
/// hard deadline.
#[test]
fn session_flood_with_low_quotas_yields_only_typed_errors() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (served, denied) = with_deadline(
        "64-session flood", Duration::from_secs(120), || {
        let dep = Arc::new(deploy(2));
        dep.admission().set_quota(
            "flood",
            TenantQuota::unlimited()
                .max_sessions(6)
                .max_in_flight(8)
                .max_kv_bytes(8 << 20),
        );
        dep.executor.set_ingress_high_water(16);
        let barrier = Arc::new(Barrier::new(64));
        let handles: Vec<_> = (0..64)
            .map(|_| {
                let dep = dep.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    let built = dep
                        .session()
                        .tenant("flood")
                        .request_timeout(Duration::from_secs(5))
                        .build();
                    let mut sess = match built {
                        Ok(s) => s,
                        Err(SymbiosisError::AdmissionDenied {
                            tenant, ..
                        }) => {
                            assert_eq!(tenant, "flood");
                            return (0u32, 1u32);
                        }
                        Err(other) => panic!(
                            "flood build failed untyped: {other}"),
                    };
                    match sess.generate(&prompt(4),
                                        &GenerationConfig::greedy(2)) {
                        Ok(_) => (1, 0),
                        Err(SymbiosisError::QuotaExceeded { .. })
                        | Err(SymbiosisError::ShardSaturated { .. })
                        | Err(SymbiosisError::WorkShed { .. })
                        | Err(SymbiosisError::DeadlineExceeded {
                            ..
                        })
                        | Err(SymbiosisError::ShardUnavailable {
                            ..
                        }) => (0, 0),
                        Err(other) => panic!(
                            "flood generate failed outside the \
                             overload family: {other}"),
                    }
                })
            })
            .collect();
        let mut served = 0u32;
        let mut denied = 0u32;
        for h in handles {
            let (ok, deny) = h.join().expect("flood thread panicked");
            served += ok;
            denied += deny;
        }
        let dep = Arc::try_unwrap(dep)
            .unwrap_or_else(|_| panic!("flood threads leaked the \
                                        deployment"));
        dep.shutdown();
        (served, denied)
    });
    assert!(served >= 1, "quotas starved every session in the flood");
    assert!(denied >= 1,
            "64 concurrent sessions against max_sessions=6 were all \
             admitted (served={served})");
}

/// Tentpole acceptance: during an ingress brown-out, background work
/// is shed with the typed wire marker while an interactive request on
/// the same shard executes and returns bit-identical output to the
/// pre-brown-out run.
#[test]
fn background_browns_out_while_interactive_stays_token_identical() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    with_deadline("ingress brown-out", Duration::from_secs(60), || {
        let dep = deploy(1);
        dep.executor.set_ingress_high_water(4);
        let sender = dep.executor.sender_for(LayerId::Qkv(0));
        let raw = |urgency: Urgency| -> LayerResponse {
            let (rtx, rrx) = channel();
            sender
                .send(ExecMsg::Request(LayerRequest {
                    client_id: 999,
                    layer: LayerId::Qkv(0),
                    op: OpKind::Forward,
                    x: Tensor::zeros(&[1, SYM_TINY.d_model]),
                    positions: None,
                    urgency,
                    resp: rtx,
                }))
                .unwrap();
            rrx.recv_timeout(Duration::from_secs(30))
                .expect("shard dropped the raw request")
        };

        let before = raw(Urgency::Interactive)
            .y
            .expect("pre-brown-out interactive request failed");

        // Phantom load one past the high-water mark: the shard stays
        // saturated even after it dequeues the next real request.
        let meter = dep.executor.ingress_meter(0);
        for _ in 0..5 {
            meter.force_admit();
        }

        let shed = raw(Urgency::Background)
            .y
            .expect_err("background work executed through a \
                         saturated shard");
        assert!(shed.starts_with(SHED_MARKER),
                "shed response missing the wire marker: {shed}");

        let after = raw(Urgency::Interactive)
            .y
            .expect("interactive request failed during the brown-out");
        assert_eq!(before, after,
                   "interactive output diverged during the brown-out");

        assert!(dep.executor.stats().per_shard[0].requests_shed >= 1,
                "the shedder never recorded the brown-out");
        dep.shutdown();
    });
}

/// Tentpole acceptance: a shard that fails every request trips its
/// breaker after the configured run, after which clients fast-fail
/// with `ShardUnavailable { retries: 0 }` instead of burning their
/// deadline against the sick shard; once the fault clears, the
/// watchdog's half-open probe lets one request through and a success
/// closes the breaker again.
#[test]
fn failing_shard_trips_breaker_then_recovers_via_probe() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    with_deadline("breaker trip and recovery", Duration::from_secs(60),
                  || {
        let dep = deploy(2);
        dep.executor.set_breaker_threshold(2);
        dep.inject_faults(FaultPlan::new(1).rule(FaultRule::on(
            0,
            FaultAction::ErrorResponse("brown shard".into()),
        )));
        let mut sick = dep
            .session()
            .request_timeout(CHAOS_TIMEOUT)
            .retry(RetryPolicy::none())
            .build()
            .unwrap();
        for _ in 0..2 {
            sick.prefill(&prompt(4))
                .expect_err("brown shard answered a prefill");
        }
        assert_ne!(dep.executor.breaker_state(0), BreakerState::Closed,
                   "two consecutive failures left the breaker closed \
                    at threshold 2");

        // While the fault persists, dispatches fast-fail without
        // touching the shard (an occasional watchdog re-arm lets one
        // probe through, which fails and reopens the breaker).
        let mut fast_failed = false;
        for _ in 0..200 {
            match sick.prefill(&prompt(4)) {
                Err(SymbiosisError::ShardUnavailable {
                    retries: 0, ..
                }) => {
                    fast_failed = true;
                    break;
                }
                Err(_) => {} // a probe slot won and failed
                Ok(_) => panic!("brown shard answered a prefill"),
            }
        }
        assert!(fast_failed,
                "open breaker never fast-failed a dispatch");
        drop(sick);

        dep.clear_faults();
        let mut fresh = dep
            .session()
            .request_timeout(Duration::from_secs(2))
            .retry(RetryPolicy::none())
            .build()
            .unwrap();
        let mut recovered = false;
        for _ in 0..400 {
            match fresh.prefill(&prompt(4)) {
                Ok(_) => {
                    recovered = true;
                    break;
                }
                Err(_) => {
                    let _ = fresh.reset();
                    std::thread::sleep(WATCHDOG_INTERVAL);
                }
            }
        }
        assert!(recovered,
                "healthy shard never re-admitted after the fault \
                 cleared");
        assert_eq!(dep.executor.breaker_state(0), BreakerState::Closed,
                   "a successful probe did not close the breaker");
        drop(fresh);
        dep.shutdown();
    });
}

/// Satellite (c): a client deadline racing `shutdown()` — a stalled
/// shard holds the request, the client's deadline fires, and the
/// fleet tears down concurrently.  Whatever interleaving the seed
/// produces, the client gets a typed error and nothing hangs.
#[test]
fn deadline_exceeded_races_fleet_shutdown() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for seed in chaos_seeds() {
        with_deadline(&format!("deadline vs shutdown, seed {seed}"),
                      Duration::from_secs(60), move || {
            let dep = deploy(2);
            // Mix before picking the shard — the default seed trio is
            // all-odd, and a bare `seed % 2` would always stall the
            // same one.
            let mut mix = seed;
            let stalled = (splitmix64(&mut mix) % 2) as usize;
            dep.inject_faults(FaultPlan::new(seed).rule(FaultRule::on(
                stalled,
                FaultAction::Stall,
            )));
            let mut sess = dep
                .session()
                .request_timeout(Duration::from_millis(50))
                .retry(RetryPolicy::none())
                .build()
                .unwrap();
            let racer = std::thread::spawn(move || {
                let out = sess.generate(&prompt(8),
                                        &GenerationConfig::greedy(4));
                drop(sess); // deregister must not hang either way
                out
            });
            std::thread::sleep(Duration::from_millis(seed % 80));
            dep.shutdown();
            let res = racer
                .join()
                .expect("client panicked racing shutdown");
            let err = res.expect_err(
                "generation succeeded through a stalled shard");
            // Any typed error is acceptable — which one wins the race
            // is the seed's business; hanging or panicking is not.
            let _ = err.to_string();
        });
    }
}
