//! Pipelined-prefill equivalence: micro-batching the prompt across the
//! shard fleet must change *when* shards work, never *what* they
//! compute.
//!
//! The acceptance bar for split-phase dispatch + pipelined prefill
//! (ISSUE 5): generation is token-identical to the sequential walk at
//! shards=1/2/4 x chunks=1/2/4 for every adapter kind (prefix included
//! — its seeded cache takes the incremental path sequentially and the
//! chunked path attends over the same cache prefix), link traffic is
//! conserved (same total bytes, message count scaling with the chunk
//! count), a shard failing mid-pipeline surfaces a typed
//! `ExecutorFailed` without deadlocking the reorder buffer, the
//! fleet-wide lockstep barrier counts clients globally, and an
//! over-committed KV cache fails with a typed `KvCacheOom` instead of
//! an analytic estimate.
//!
//! Tests skip when artifacts are absent (same convention as
//! `integration.rs`).

use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             FaultAction, FaultPlan, FaultRule,
                             GenerationConfig, Placement,
                             SymbiosisError};
use symbiosis::device::{DeviceKind, MemoryLedger};
use symbiosis::runtime::Engine;
use symbiosis::transport::LinkKind;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

/// One engine (compile cache) shared by every deployment in this file.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(&artifact_dir()).unwrap()))
        .clone()
}

fn deploy(shards: usize, policy: BatchPolicy) -> Deployment {
    let placement = if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  policy, placement)
        .unwrap()
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i * 7 + 3) as i32 % 256).collect()
}

/// Greedy generation, optionally pipelined, for one adapter kind.
fn generate_on(shards: usize, chunk: Option<usize>,
               adapter: Option<Adapter>) -> Vec<Vec<i32>> {
    let dep = deploy(shards, BatchPolicy::NoLockstep);
    let mut b = dep.session();
    if let Some(a) = adapter {
        b = b.adapter(a);
    }
    if let Some(c) = chunk {
        b = b.prefill_chunk(c);
    }
    let mut sess = b.build().unwrap();
    let out = sess
        .generate(&prompt(16), &GenerationConfig::greedy(10))
        .unwrap();
    drop(sess);
    dep.shutdown();
    out
}

fn lora8() -> Adapter {
    Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                 LoraTargets::QKVO, 2.0)
        .unwrap()
}

/// Tentpole acceptance: generation (prefill through the pipelined walk,
/// then decode against the cache it filled) is token-identical to the
/// sequential walk at every shards x chunks point, for every adapter
/// kind.  The prefix row also covers prefix-seeded incremental prefill:
/// sequentially a seeded cache routes incrementally, pipelined it
/// attends over the same seeded prefix.
#[test]
fn pipelined_generation_is_identical_across_shards_and_chunks() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let adapters: Vec<(&str, fn() -> Option<Adapter>)> = vec![
        ("base", || None),
        ("lora", || Some(lora8())),
        ("ia3", || Some(Adapter::ia3(&SYM_TINY))),
        ("prefix", || Some(Adapter::prefix(&SYM_TINY, 1, 4, 11))),
    ];
    // prompt is 16 columns: chunks=1/2/4 -> 16/8/4 columns per chunk
    for (label, mk) in adapters {
        let golden = generate_on(1, None, mk());
        for shards in [1usize, 2, 4] {
            for chunks in [1usize, 2, 4] {
                let chunk_cols = 16 / chunks;
                let got = generate_on(shards, Some(chunk_cols), mk());
                assert_eq!(got, golden,
                           "{label}: shards={shards} chunks={chunks} \
                            diverged from the sequential walk");
            }
        }
    }
}

/// Batched prompts chunk along the token axis per sequence: the
/// pipelined walk at batch=2 must match the sequential batch prefill.
#[test]
fn pipelined_generation_matches_at_batch_two() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let toks = prompt(24); // 2 sequences x 12 columns, token-major
    let run = |chunk: Option<usize>| {
        let dep = deploy(2, BatchPolicy::NoLockstep);
        let mut b = dep.session().batch(2);
        if let Some(c) = chunk {
            b = b.prefill_chunk(c);
        }
        let mut sess = b.build().unwrap();
        let out =
            sess.generate(&toks, &GenerationConfig::greedy(8)).unwrap();
        drop(sess);
        dep.shutdown();
        out
    };
    let golden = run(None);
    for chunk_cols in [4usize, 6] {
        assert_eq!(run(Some(chunk_cols)), golden,
                   "batch=2 chunk_cols={chunk_cols} diverged");
    }
}

/// Link-traffic conservation: chunking moves the same activation rows
/// in more, smaller messages — total bytes unchanged, message count
/// scaling exactly with the chunk count.
#[test]
fn pipelined_link_traffic_is_conserved() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2, BatchPolicy::NoLockstep);
    let toks = prompt(32);
    let chunks = 4usize;
    let traffic = |chunk: Option<usize>| {
        // NvLink everywhere so bytes are counted (SharedLocal counts
        // messages only)
        let mut b = dep.session().link(LinkKind::NvLink);
        if let Some(c) = chunk {
            b = b.prefill_chunk(c);
        }
        let mut sess = b.build().unwrap();
        if let Some(c) = chunk {
            sess.prefill_pipelined(&toks, c).unwrap();
        } else {
            sess.prefill(&toks).unwrap();
        }
        let t = sess.core.virt.link_traffic();
        let msgs: u64 = t.iter().map(|(m, _)| m).sum();
        let bytes: u64 = t.iter().map(|(_, b)| b).sum();
        (msgs, bytes)
    };
    let (seq_msgs, seq_bytes) = traffic(None);
    let (pipe_msgs, pipe_bytes) = traffic(Some(32 / chunks));
    assert_eq!(pipe_bytes, seq_bytes,
               "chunking must move the same total bytes");
    assert_eq!(pipe_msgs, seq_msgs * chunks as u64,
               "each micro-batch performs the full walk's messages");
    dep.shutdown();
}

/// A shard failing mid-pipeline must surface a typed `ExecutorFailed`
/// on collect without deadlocking the reorder buffer (the remaining
/// in-flight receivers unwind with the driver).
#[test]
fn failing_shard_mid_pipeline_surfaces_typed_error() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2, BatchPolicy::NoLockstep);
    // Fault-inject shard 1: every request to it answers a typed
    // failure, like a shard whose engine rejects every flush.  Blocks
    // 0-1 still ride the healthy shard 0.
    dep.inject_faults(FaultPlan::new(11).rule(FaultRule::on(
        1,
        FaultAction::ErrorResponse("injected shard fault".into()),
    )));
    let mut sess = dep.session().build().unwrap();

    let (done_tx, done_rx) = channel();
    let handle = std::thread::spawn(move || {
        let result = sess.prefill_pipelined(&prompt(16), 4);
        let _ = done_tx.send(());
        result
    });
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("pipelined prefill deadlocked on a failing shard");
    let err = handle.join().unwrap().unwrap_err();
    match err {
        SymbiosisError::ExecutorFailed { layer, message } => {
            assert_eq!(message, "injected shard fault");
            assert!(!layer.is_empty());
        }
        other => panic!("expected ExecutorFailed, got {other}"),
    }
    dep.shutdown();
}

/// Satellite: `BatchPolicy::LockstepFleet` counts registrations at the
/// fleet, not the shard — the shared barrier sees every client once,
/// and concurrent generation under the global barrier still matches
/// the unbatched outputs.
#[test]
fn fleet_lockstep_counts_globally_and_preserves_outputs() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // golden from an uncontended run
    let golden = generate_on(2, None, None);

    let dep = deploy(2, BatchPolicy::LockstepFleet);
    let a = dep.session().build().unwrap();
    let b = dep.session().build().unwrap();
    // clients bump the fleet count synchronously at registration, so
    // the global barrier sees both the moment `build` returns
    assert_eq!(dep.executor.barrier().registered(), 2,
               "fleet barrier must count each client exactly once");
    let run = |mut sess: symbiosis::coordinator::InferenceSession| {
        std::thread::spawn(move || {
            sess.generate(&prompt(16), &GenerationConfig::greedy(10))
                .unwrap()
        })
    };
    let (ha, hb) = (run(a), run(b));
    let (out_a, out_b) = (ha.join().unwrap(), hb.join().unwrap());
    assert_eq!(out_a, golden, "client A diverged under LockstepFleet");
    assert_eq!(out_b, golden, "client B diverged under LockstepFleet");
    // session drop deregisters synchronously (the threads dropped the
    // sessions before join returned)
    assert_eq!(dep.executor.barrier().registered(), 0,
               "fleet barrier leaked registrations");
    let stats = dep.shutdown();
    assert!(stats.n_flushes > 0);
}

/// Satellite: session KV bytes charge the client device's ledger, so an
/// over-committed deployment fails a request with a typed `KvCacheOom`
/// — and freeing one tenant's cache lets the next one in.
#[test]
fn over_committed_kv_cache_fails_typed_then_recovers() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(1, BatchPolicy::NoLockstep);
    // A 64-token sym-tiny cache is 2*4 layers*4 heads*64*16*4 B =
    // 128 KiB; size the client device to hold exactly one of them.
    let one_cache: u64 = 2 * 4 * 4 * 64 * 16 * 4;
    dep.client_device.lock().unwrap().ledger =
        MemoryLedger::new(one_cache + 1024);

    let mut a = dep.session().build().unwrap();
    a.prefill(&prompt(64)).unwrap(); // fits alone

    let mut b = dep.session().build().unwrap();
    let err = b.prefill(&prompt(64)).unwrap_err();
    match err {
        SymbiosisError::KvCacheOom { need_bytes, used_bytes,
                                     capacity_bytes } => {
            assert_eq!(capacity_bytes, one_cache + 1024);
            // the blame lands on the co-tenant: B's cache alone fits
            assert_eq!(used_bytes, one_cache);
            // the paged cache allocates 16-token blocks; the failing
            // unit is one block, not the whole request
            assert_eq!(need_bytes, 2 * 4 * 16 * 16 * 4);
            assert!(need_bytes <= capacity_bytes);
        }
        other => panic!("expected KvCacheOom, got {other}"),
    }
    // the failed growth charged nothing and left B usable: once A
    // leaves, the same request fits
    drop(a);
    b.prefill(&prompt(64))
        .expect("B must fit after A released its cache");
    drop(b);
    dep.shutdown();
}

/// Satellite: the host device is a separate pool — host-offloaded
/// caches do not compete with device-resident ones.
#[test]
fn host_offloaded_cache_charges_the_host_ledger() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use symbiosis::coordinator::KvPlacement;
    let dep = deploy(1, BatchPolicy::NoLockstep);
    // client device too small for any cache; host is huge
    dep.client_device.lock().unwrap().ledger = MemoryLedger::new(1024);
    let mut sess = dep
        .session()
        .kv(KvPlacement::Host)
        .build()
        .unwrap();
    sess.prefill(&prompt(64))
        .expect("host-offloaded cache must not charge the client device");
    let host_used = dep.host_device.lock().unwrap().ledger.used();
    assert!(host_used > 0, "host ledger uncharged");
    assert_eq!(dep.client_device.lock().unwrap().ledger.used(), 0);
    drop(sess);
    assert_eq!(dep.host_device.lock().unwrap().ledger.used(), 0,
               "drop must release the host charge");
    dep.shutdown();
}

/// The per-request `GenerationConfig::with_prefill_chunk` overrides the
/// session default and still matches sequential outputs.
#[test]
fn per_request_prefill_chunk_override_matches() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let golden = generate_on(2, None, None);
    let dep = deploy(2, BatchPolicy::NoLockstep);
    let mut sess = dep.session().build().unwrap(); // no session default
    let cfg = GenerationConfig::greedy(10).with_prefill_chunk(4);
    let out = sess.generate(&prompt(16), &cfg).unwrap();
    assert_eq!(out, golden, "per-request chunk override diverged");
    drop(sess);
    dep.shutdown();
}

/// Verify the tiny-device constant used by the OOM test stays in sync
/// with the config (sanity that runs without artifacts).
#[test]
fn kv_oom_test_constant_matches_config() {
    let bh = SYM_TINY.n_heads; // batch = 1
    let bytes = 2 * SYM_TINY.n_layers * bh * 64 * SYM_TINY.d_head() * 4;
    assert_eq!(bytes as u64, 2 * 4 * 4 * 64 * 16 * 4);
    assert!(DeviceKind::GpuA100_80.capacity() > bytes as u64);
}
