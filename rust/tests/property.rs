//! Property-based tests over coordinator invariants.
//!
//! proptest is not in the vendored registry (DESIGN.md section 8), so
//! this file carries a minimal deterministic strategy framework: a
//! splitmix64 RNG drives randomized cases; failures print the case seed
//! so they can be replayed exactly.

use symbiosis::config::{bucket_for, SEQ_BUCKETS, TOKEN_BUCKETS};
use symbiosis::coordinator::kv_cache::{KvCache, KvPlacement};
use symbiosis::coordinator::optimizer::Adam;
use symbiosis::device::MemoryLedger;
use symbiosis::tensor::{ops, Tensor};

// ---------------------------------------------------------------------
// mini framework
// ---------------------------------------------------------------------

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed)
    }

    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo)
    }

    fn f32(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }

    fn tensor(&mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_f32((0..n).map(|_| self.f32()).collect(), shape)
    }
}

/// Run `f` over `cases` deterministic seeds; panic message carries the
/// seed for replay.
fn for_all<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed * 7919 + 13);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------
// buckets
// ---------------------------------------------------------------------

#[test]
fn prop_bucket_is_minimal_cover() {
    for_all("bucket_minimal", 500, |rng| {
        let n = rng.range(1, 2049);
        let b = bucket_for(n, TOKEN_BUCKETS).unwrap();
        assert!(b >= n);
        // minimal: no smaller bucket covers n
        for &other in TOKEN_BUCKETS {
            if other < b {
                assert!(other < n);
            }
        }
        // bounded padding overhead: bucket < 2n (buckets are pow2-spaced)
        assert!(b < 2 * n.max(TOKEN_BUCKETS[0]));
    });
}

// ---------------------------------------------------------------------
// memory ledger
// ---------------------------------------------------------------------

#[test]
fn prop_ledger_balanced_under_random_ops() {
    for_all("ledger_balanced", 200, |rng| {
        let cap = rng.range(1000, 100_000) as u64;
        let mut ledger = MemoryLedger::new(cap);
        let tags: Vec<String> =
            (0..rng.range(2, 8)).map(|i| format!("t{i}")).collect();
        for _ in 0..rng.range(10, 100) {
            let tag = &tags[rng.range(0, tags.len())];
            match rng.range(0, 3) {
                0 => {
                    let _ = ledger.set(tag, rng.next() % (cap / 2));
                }
                1 => {
                    let _ = ledger.grow(tag, rng.next() % (cap / 8));
                }
                _ => ledger.free(tag),
            }
            assert!(ledger.check_balanced());
            assert!(ledger.used() <= ledger.capacity());
            assert!(ledger.peak() >= ledger.used());
        }
    });
}

// ---------------------------------------------------------------------
// KV cache vs naive reference
// ---------------------------------------------------------------------

#[test]
fn prop_kv_cache_matches_naive_reference() {
    for_all("kv_cache_ref", 50, |rng| {
        let n_layers = rng.range(1, 4);
        let bh = rng.range(1, 5);
        let h = rng.range(2, 9);
        let mut cache =
            KvCache::new(n_layers, bh, h, KvPlacement::Device);
        // naive reference: per layer, per bh, Vec of rows
        let mut refk = vec![vec![Vec::<f32>::new(); bh]; n_layers];
        let mut refv = vec![vec![Vec::<f32>::new(); bh]; n_layers];
        for _ in 0..rng.range(1, 12) {
            let t_new = rng.range(1, 5);
            for layer in 0..n_layers {
                let k = rng.tensor(&[bh, t_new, h]);
                let v = rng.tensor(&[bh, t_new, h]);
                cache.append(layer, &k, &v).unwrap();
                for b in 0..bh {
                    for t in 0..t_new {
                        let off = (b * t_new + t) * h;
                        refk[layer][b]
                            .extend_from_slice(&k.as_f32()[off..off + h]);
                        refv[layer][b]
                            .extend_from_slice(&v.as_f32()[off..off + h]);
                    }
                }
            }
        }
        let len = cache.len();
        let bucket = bucket_for(len, SEQ_BUCKETS).unwrap();
        for layer in 0..n_layers {
            let (k, v) = cache.padded(layer, bucket);
            for b in 0..bh {
                let got = &k.as_f32()[b * bucket * h..][..len * h];
                assert_eq!(got, &refk[layer][b][..],
                           "layer {layer} bh {b} K mismatch");
                let gotv = &v.as_f32()[b * bucket * h..][..len * h];
                assert_eq!(gotv, &refv[layer][b][..]);
                // padding region is zero
                for x in
                    &k.as_f32()[b * bucket * h + len * h..(b + 1) * bucket * h]
                {
                    assert_eq!(*x, 0.0);
                }
            }
        }
    });
}

// ---------------------------------------------------------------------
// tensor ops
// ---------------------------------------------------------------------

#[test]
fn prop_slice_concat_roundtrip() {
    for_all("slice_concat", 200, |rng| {
        let rows = rng.range(1, 30);
        let cols = rng.range(1, 20);
        let t = rng.tensor(&[rows, cols]);
        let cut = rng.range(0, rows + 1);
        if cut == 0 || cut == rows {
            return;
        }
        let a = t.slice_rows(0, cut);
        let b = t.slice_rows(cut, rows);
        assert_eq!(Tensor::concat_rows(&[&a, &b]), t);
    });
}

#[test]
fn prop_zero_copy_views_match_seed_copying_semantics() {
    // slice_rows is now a zero-copy view and concat/pad assembly is a
    // single fused pass; both must stay bit-identical to the seed's
    // copy-based reference implementations.
    for_all("views_match_copies", 200, |rng| {
        let rows = rng.range(1, 24);
        let cols = rng.range(1, 16);
        let t = rng.tensor(&[rows, cols]);
        let lo = rng.range(0, rows);
        let hi = lo + rng.range(0, rows - lo + 1);
        let view = t.slice_rows(lo, hi);
        // seed reference: copy the row range out
        let want = Tensor::from_f32(
            t.as_f32()[lo * cols..hi * cols].to_vec(), &[hi - lo, cols]);
        assert_eq!(view, want, "slice_rows view != copied slice");

        // fused concat+pad vs the seed's two-pass reference
        let n_parts = rng.range(1, 5);
        let mut parts: Vec<Tensor> = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            let r = rng.range(1, 6);
            parts.push(rng.tensor(&[r, cols]));
        }
        let refs: Vec<&Tensor> = parts.iter().collect();
        let total: usize = parts.iter().map(|p| p.shape[0]).sum();
        let bucket = total + rng.range(0, 8);
        let fused = Tensor::concat_rows_padded(&refs, bucket);
        let mut seed = Vec::new();
        for p in &parts {
            seed.extend_from_slice(p.as_f32());
        }
        seed.resize(bucket * cols, 0.0);
        assert_eq!(fused, Tensor::from_f32(seed, &[bucket, cols]),
                   "fused assembly != concat_rows + pad_rows");
    });
}

#[test]
fn prop_copy_on_write_never_aliases_sibling_views() {
    for_all("cow_no_alias", 200, |rng| {
        let rows = rng.range(2, 16);
        let cols = rng.range(1, 12);
        let mut parent = rng.tensor(&[rows, cols]);
        let cut = rng.range(1, rows);
        let mut view_a = parent.slice_rows(0, cut);
        let view_b = parent.slice_rows(cut, rows);
        let clone = parent.clone();
        let snap_parent: Vec<f32> = parent.as_f32().to_vec();
        let snap_b: Vec<f32> = view_b.as_f32().to_vec();
        let snap_clone: Vec<f32> = clone.as_f32().to_vec();

        // mutate the first view through every mutating entry point
        let delta = rng.tensor(&[cut, cols]);
        ops::add_assign(&mut view_a, &delta);
        ops::add_scaled(&mut view_a, &delta, rng.f32());
        view_a.as_f32_mut()[0] += 1.0;
        assert_eq!(parent.as_f32(), &snap_parent[..],
                   "view mutation leaked into parent");
        assert_eq!(view_b.as_f32(), &snap_b[..],
                   "view mutation leaked into sibling view");

        // and mutate the parent: outstanding views/clones must hold
        let snap_a: Vec<f32> = view_a.as_f32().to_vec();
        parent.as_f32_mut()[rng.range(0, rows * cols)] = 42.0;
        assert_eq!(view_a.as_f32(), &snap_a[..],
                   "parent mutation leaked into view");
        assert_eq!(view_b.as_f32(), &snap_b[..],
                   "parent mutation leaked into view");
        assert_eq!(clone.as_f32(), &snap_clone[..],
                   "parent mutation leaked into clone");
    });
}

#[test]
fn prop_head_split_merge_roundtrip() {
    for_all("head_roundtrip", 200, |rng| {
        let nh = [1usize, 2, 4, 8][rng.range(0, 4)];
        let h = rng.range(1, 10);
        let t = rng.range(1, 20);
        let x = rng.tensor(&[t, nh * h]);
        assert_eq!(x.split_heads(nh).merge_heads(), x);
    });
}

#[test]
fn prop_pad_rows_preserves_prefix() {
    for_all("pad_rows", 200, |rng| {
        let rows = rng.range(1, 20);
        let cols = rng.range(1, 16);
        let x = rng.tensor(&[rows, cols]);
        let padded = x.pad_rows(rows + rng.range(0, 10));
        assert_eq!(&padded.as_f32()[..rows * cols], x.as_f32());
        for v in &padded.as_f32()[rows * cols..] {
            assert_eq!(*v, 0.0);
        }
    });
}

#[test]
fn prop_privacy_arithmetic_is_exact_for_linear() {
    // (x + n) W - nW == x W for arbitrary x, n, W (fp tolerance) —
    // the linearity that makes the noise protocol exact.
    for_all("privacy_linear", 100, |rng| {
        let t = rng.range(1, 10);
        let din = rng.range(1, 12);
        let dout = rng.range(1, 12);
        let x = rng.tensor(&[t, din]);
        let n = rng.tensor(&[t, din]);
        let w = rng.tensor(&[din, dout]);
        let noisy = ops::matmul(&ops::add(&x, &n), &w);
        let n_eff = ops::matmul(&n, &w);
        let recovered = ops::sub(&noisy, &n_eff);
        let want = ops::matmul(&x, &w);
        assert!(recovered.max_abs_diff(&want) < 1e-4);
    });
}

#[test]
fn prop_rmsnorm_bwd_matches_finite_difference() {
    for_all("rmsnorm_fd", 30, |rng| {
        let d = rng.range(2, 10);
        let x = rng.tensor(&[1, d]);
        let gain = rng.tensor(&[d]);
        let dy = rng.tensor(&[1, d]);
        let grad = ops::rmsnorm_bwd(&x, &gain, &dy);
        let eps = 1e-3f32;
        for i in 0..d {
            let mut xp = x.clone();
            xp.as_f32_mut()[i] += eps;
            let mut xm = x.clone();
            xm.as_f32_mut()[i] -= eps;
            let fd: f32 = ops::rmsnorm(&xp, &gain)
                .as_f32()
                .iter()
                .zip(ops::rmsnorm(&xm, &gain).as_f32())
                .zip(dy.as_f32())
                .map(|((p, m), g)| (p - m) / (2.0 * eps) * g)
                .sum();
            assert!((fd - grad.as_f32()[i]).abs() < 3e-2,
                    "d{i}: fd {fd} vs {}", grad.as_f32()[i]);
        }
    });
}

// ---------------------------------------------------------------------
// optimizer: native == artifact formula
// ---------------------------------------------------------------------

#[test]
fn prop_adam_native_monotone_moments() {
    for_all("adam_native", 100, |rng| {
        let n = rng.range(1, 50);
        let mut adam = Adam::new(n);
        let mut p: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let p0 = p.clone();
        let g: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        adam.step_native(&mut p, &g);
        for i in 0..n {
            if g[i] == 0.0 {
                assert_eq!(p[i], p0[i], "zero grad moved a param");
            } else {
                // step direction opposes gradient
                assert!((p0[i] - p[i]).signum() == g[i].signum()
                        || (p0[i] - p[i]).abs() < 1e-9);
            }
        }
    });
}

// ---------------------------------------------------------------------
// end-to-end randomized batching invariance (needs artifacts)
// ---------------------------------------------------------------------

#[test]
fn prop_executor_batching_matches_direct_execution() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use symbiosis::coordinator::proto::{LayerId, Urgency};
    use symbiosis::coordinator::{BatchPolicy, Deployment, Placement};
    let dep = Deployment::start(&symbiosis::config::SYM_TINY, &dir,
                                BatchPolicy::opportunistic_default(),
                                Placement::Local)
        .unwrap();
    let engine = dep.engine.clone();
    let weights = symbiosis::tensor::container::read_tensors(
        &dir.join("weights_sym-tiny.bin"))
        .unwrap();

    // random per-client token counts, concurrent submissions — each
    // client's result must equal a direct single-tensor execution.
    for_all("exec_batching", 5, |rng| {
        let n_clients = rng.range(2, 5);
        let mut handles = Vec::new();
        for _ in 0..n_clients {
            let t = rng.range(1, 24);
            let x = rng.tensor(&[t, 64]);
            let core = dep.client_core(None);
            let engine = engine.clone();
            let w = weights["l0.wqkv"].clone();
            let b = weights["l0.bqkv"].clone();
            handles.push(std::thread::spawn(move || {
                let got = core
                    .virt
                    .forward(LayerId::Qkv(0), x.clone(),
                             Urgency::Training)
                    .unwrap();
                // direct execution (unbatched) for comparison
                let bucket = bucket_for(t, TOKEN_BUCKETS).unwrap();
                let name = format!("linear_fwd_t{bucket}_64x192");
                let direct = engine
                    .execute(&name, &[&x.pad_rows(bucket), &w, &b])
                    .unwrap()[0]
                    .slice_rows(0, t);
                assert!(got.max_abs_diff(&direct) < 1e-4,
                        "batched != direct");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}
