//! Property-based tests over coordinator invariants.
//!
//! proptest is not in the vendored registry (DESIGN.md section 8), so
//! this file carries a minimal deterministic strategy framework: a
//! splitmix64 RNG drives randomized cases; failures print the case seed
//! so they can be replayed exactly.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use symbiosis::config::{bucket_for, SEQ_BUCKETS, TOKEN_BUCKETS};
use symbiosis::coordinator::kv_cache::{
    BlockPool, KvCache, KvPlacement, PrefixMeta,
};
use symbiosis::coordinator::optimizer::Adam;
use symbiosis::device::{Device, DeviceKind, MemoryLedger};
use symbiosis::tensor::{ops, Tensor};

// ---------------------------------------------------------------------
// mini framework
// ---------------------------------------------------------------------

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed)
    }

    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next() as usize) % (hi - lo)
    }

    fn f32(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
    }

    fn tensor(&mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_f32((0..n).map(|_| self.f32()).collect(), shape)
    }
}

/// Run `f` over `cases` deterministic seeds; panic message carries the
/// seed for replay.
fn for_all<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(seed * 7919 + 13);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed}: {e:?}");
        }
    }
}

// ---------------------------------------------------------------------
// buckets
// ---------------------------------------------------------------------

#[test]
fn prop_bucket_is_minimal_cover() {
    for_all("bucket_minimal", 500, |rng| {
        let n = rng.range(1, 2049);
        let b = bucket_for(n, TOKEN_BUCKETS).unwrap();
        assert!(b >= n);
        // minimal: no smaller bucket covers n
        for &other in TOKEN_BUCKETS {
            if other < b {
                assert!(other < n);
            }
        }
        // bounded padding overhead: bucket < 2n (buckets are pow2-spaced)
        assert!(b < 2 * n.max(TOKEN_BUCKETS[0]));
    });
}

// ---------------------------------------------------------------------
// memory ledger
// ---------------------------------------------------------------------

#[test]
fn prop_ledger_balanced_under_random_ops() {
    for_all("ledger_balanced", 200, |rng| {
        let cap = rng.range(1000, 100_000) as u64;
        let mut ledger = MemoryLedger::new(cap);
        let tags: Vec<String> =
            (0..rng.range(2, 8)).map(|i| format!("t{i}")).collect();
        for _ in 0..rng.range(10, 100) {
            let tag = &tags[rng.range(0, tags.len())];
            match rng.range(0, 3) {
                0 => {
                    let _ = ledger.set(tag, rng.next() % (cap / 2));
                }
                1 => {
                    let _ = ledger.grow(tag, rng.next() % (cap / 8));
                }
                _ => ledger.free(tag),
            }
            assert!(ledger.check_balanced());
            assert!(ledger.used() <= ledger.capacity());
            assert!(ledger.peak() >= ledger.used());
        }
    });
}

// ---------------------------------------------------------------------
// KV cache vs naive reference
// ---------------------------------------------------------------------

#[test]
fn prop_kv_cache_matches_naive_reference() {
    for_all("kv_cache_ref", 50, |rng| {
        let n_layers = rng.range(1, 4);
        let bh = rng.range(1, 5);
        let h = rng.range(2, 9);
        let mut cache =
            KvCache::new(n_layers, bh, h, KvPlacement::Device);
        // naive reference: per layer, per bh, Vec of rows
        let mut refk = vec![vec![Vec::<f32>::new(); bh]; n_layers];
        let mut refv = vec![vec![Vec::<f32>::new(); bh]; n_layers];
        for _ in 0..rng.range(1, 12) {
            let t_new = rng.range(1, 5);
            for layer in 0..n_layers {
                let k = rng.tensor(&[bh, t_new, h]);
                let v = rng.tensor(&[bh, t_new, h]);
                cache.append(layer, &k, &v).unwrap();
                for b in 0..bh {
                    for t in 0..t_new {
                        let off = (b * t_new + t) * h;
                        refk[layer][b]
                            .extend_from_slice(&k.as_f32()[off..off + h]);
                        refv[layer][b]
                            .extend_from_slice(&v.as_f32()[off..off + h]);
                    }
                }
            }
        }
        let len = cache.len();
        let bucket = bucket_for(len, SEQ_BUCKETS).unwrap();
        for layer in 0..n_layers {
            let (k, v) = cache.padded(layer, bucket);
            for b in 0..bh {
                let got = &k.as_f32()[b * bucket * h..][..len * h];
                assert_eq!(got, &refk[layer][b][..],
                           "layer {layer} bh {b} K mismatch");
                let gotv = &v.as_f32()[b * bucket * h..][..len * h];
                assert_eq!(gotv, &refv[layer][b][..]);
                // padding region is zero
                for x in
                    &k.as_f32()[b * bucket * h + len * h..(b + 1) * bucket * h]
                {
                    assert_eq!(*x, 0.0);
                }
            }
        }
    });
}

// ---------------------------------------------------------------------
// paged block allocator vs reference refcount model
// ---------------------------------------------------------------------

/// Reference model of the block pool: model block ids with refcounts,
/// mirrored through the same alloc / CoW-fork / publish / adopt /
/// release rules the real allocator implements.  After every operation
/// the pool's live-block count and ledger charges must match the model
/// exactly — a leak or double-free in either direction diverges.
struct BlockModel {
    refs: HashMap<u64, usize>,
    next: u64,
    registry: HashMap<String, ModelEntry>,
}

struct ModelEntry {
    layers: Vec<Vec<u64>>,
    users: usize,
    len: usize,
}

/// Model mirror of one cache's block tables.
struct CacheModel {
    tables: Vec<Vec<u64>>,
    len: usize,
    entries: Vec<String>,
}

impl BlockModel {
    fn alloc(&mut self) -> u64 {
        self.next += 1;
        self.refs.insert(self.next, 1);
        self.next
    }

    fn live(&self) -> usize {
        self.refs.len()
    }

    fn deref(&mut self, id: u64) {
        let r = self.refs.get_mut(&id).expect("model double-free");
        *r -= 1;
        if *r == 0 {
            self.refs.remove(&id);
        }
    }

    fn release_entry(&mut self, key: &str) {
        let drained = {
            let e = self.registry.get_mut(key).expect("unknown entry");
            e.users -= 1;
            e.users == 0
        };
        if drained {
            let e = self.registry.remove(key).expect("entry vanished");
            for layer in e.layers {
                for id in layer {
                    self.deref(id);
                }
            }
        }
    }
}

/// Mirror of `KvCache::append`: for every block index the write touches,
/// fork it when shared (refs > 1), allocate it when missing.
fn model_append(bm: &mut BlockModel, cm: &mut CacheModel, t: usize,
                bt: usize) {
    let old = cm.len;
    let need = (old + t).div_ceil(bt);
    for table in &mut cm.tables {
        let have = table.len();
        for bi in old / bt..need {
            if bi < have {
                let id = table[bi];
                if bm.refs[&id] > 1 {
                    bm.deref(id);
                    table[bi] = bm.alloc();
                }
            } else {
                table.push(bm.alloc());
            }
        }
    }
    cm.len += t;
}

/// Mirror of `KvCache::publish_prefix`.
fn model_publish(bm: &mut BlockModel, cm: &mut CacheModel, key: &str,
                 bt: usize) -> bool {
    if bm.registry.contains_key(key) {
        return false;
    }
    let nblocks = cm.len.div_ceil(bt);
    let layers: Vec<Vec<u64>> =
        cm.tables.iter().map(|t| t[..nblocks].to_vec()).collect();
    for layer in &layers {
        for &id in layer {
            *bm.refs.get_mut(&id).expect("published unknown block") += 1;
        }
    }
    bm.registry.insert(
        key.to_string(),
        ModelEntry { layers, users: 1, len: cm.len },
    );
    cm.entries.push(key.to_string());
    true
}

/// Mirror of `KvCache::adopt_prefix`.
fn model_adopt(bm: &mut BlockModel, cm: &mut CacheModel, key: &str)
               -> bool {
    let (layers, len) = match bm.registry.get_mut(key) {
        Some(e) => {
            e.users += 1;
            (e.layers.clone(), e.len)
        }
        None => return false,
    };
    for layer in &layers {
        for &id in layer {
            *bm.refs.get_mut(&id).expect("adopted unknown block") += 1;
        }
    }
    cm.tables = layers;
    cm.len = len;
    cm.entries.push(key.to_string());
    true
}

/// Mirror of `KvCache::drop`.
fn model_drop(bm: &mut BlockModel, cm: CacheModel) {
    for key in cm.entries {
        bm.release_entry(&key);
    }
    for table in cm.tables {
        for id in table {
            bm.deref(id);
        }
    }
}

#[test]
fn prop_block_allocator_matches_reference_model() {
    for_all("block_alloc", 30, |rng| {
        let layers = rng.range(1, 4);
        let (bh, h, bt) = (2usize, 4usize, 4usize);
        let bb = (2 * bh * bt * h * 4) as u64;
        let pool = BlockPool::with_block_tokens(bt);
        let mk_dev = |name: &str| {
            let mut d = Device::new(name, DeviceKind::Cpu);
            d.ledger = MemoryLedger::new(4 << 20);
            Arc::new(Mutex::new(d))
        };
        let dev = mk_dev("prop-dev");
        let host = mk_dev("prop-host");

        let mut bm = BlockModel {
            refs: HashMap::new(),
            next: 0,
            registry: HashMap::new(),
        };
        let mut caches: Vec<Option<(KvCache, CacheModel)>> =
            (0..4).map(|_| None).collect();
        let keys = ["pfx-a", "pfx-b", "pfx-c"];
        let mut tag_seq = 0usize;

        for _ in 0..rng.range(20, 60) {
            let slot = rng.range(0, caches.len());
            match rng.range(0, 6) {
                0 => {
                    // (re)create the slot's cache, sometimes adopting a
                    // published prefix into it
                    if caches[slot].is_none() {
                        let mut c =
                            KvCache::new(layers, bh, h, KvPlacement::Device);
                        c.set_pool(pool.clone()).unwrap();
                        tag_seq += 1;
                        c.attach_ledger(dev.clone(),
                                        format!("kv:prop{tag_seq}"))
                            .unwrap();
                        c.attach_swap(host.clone());
                        c.set_background(rng.range(0, 2) == 0);
                        let mut cm = CacheModel {
                            tables: vec![Vec::new(); layers],
                            len: 0,
                            entries: Vec::new(),
                        };
                        if rng.range(0, 2) == 0 {
                            let key = keys[rng.range(0, keys.len())];
                            let adopted =
                                c.adopt_prefix(key).unwrap().is_some();
                            assert_eq!(adopted,
                                       model_adopt(&mut bm, &mut cm, key),
                                       "adopt outcome diverged on {key}");
                        }
                        caches[slot] = Some((c, cm));
                    }
                }
                1 | 2 => {
                    // append the same token count to every layer (keeps
                    // layer lengths uniform so publish stays legal)
                    if let Some((c, cm)) = caches[slot].as_mut() {
                        let t = rng.range(1, 9);
                        for l in 0..layers {
                            let k = rng.tensor(&[bh, t, h]);
                            let v = rng.tensor(&[bh, t, h]);
                            c.append(l, &k, &v).unwrap();
                        }
                        model_append(&mut bm, cm, t, bt);
                        if cm.len > 0 && rng.range(0, 3) == 0 {
                            let l = rng.range(0, layers);
                            let bucket =
                                bucket_for(cm.len, SEQ_BUCKETS).unwrap();
                            let (pk, pv) = c.padded(l, bucket);
                            let (gk, gv) =
                                c.padded_view(l, bucket).unwrap();
                            assert_eq!(gk, pk, "padded_view K diverged");
                            assert_eq!(gv, pv, "padded_view V diverged");
                        }
                    }
                }
                3 => {
                    if let Some((c, cm)) = caches[slot].as_mut() {
                        let key = keys[rng.range(0, keys.len())];
                        let published = c
                            .publish_prefix(key, PrefixMeta::default())
                            .unwrap();
                        assert_eq!(published,
                                   model_publish(&mut bm, cm, key, bt),
                                   "publish outcome diverged on {key}");
                    }
                }
                4 => {
                    // clear keeps blocks; swap moves charges, not refs
                    if let Some((c, cm)) = caches[slot].as_mut() {
                        if rng.range(0, 2) == 0 {
                            c.clear();
                            cm.len = 0;
                        } else {
                            c.swap_out_all().unwrap();
                        }
                    }
                }
                _ => {
                    if let Some((c, cm)) = caches[slot].take() {
                        drop(c);
                        model_drop(&mut bm, cm);
                    }
                }
            }

            // invariants after every op: no leaked or double-freed
            // blocks, and ledger charge == live blocks x block bytes
            assert_eq!(pool.live_blocks(), bm.live(),
                       "live block count diverged from model");
            let (d, hst) = pool.charged_bytes();
            assert_eq!(d + hst, bm.live() as u64 * bb,
                       "charge != live blocks x block bytes");
            {
                let dl = dev.lock().unwrap();
                assert!(dl.ledger.check_balanced());
                assert_eq!(dl.ledger.used(), d, "device ledger drifted");
            }
            {
                let hl = host.lock().unwrap();
                assert!(hl.ledger.check_balanced());
                assert_eq!(hl.ledger.used(), hst, "host ledger drifted");
            }
        }

        // drain: every reference released, nothing charged anywhere
        for slot in caches.iter_mut() {
            if let Some((c, cm)) = slot.take() {
                drop(c);
                model_drop(&mut bm, cm);
            }
        }
        assert_eq!(pool.live_blocks(), 0, "blocks leaked after drain");
        assert_eq!(bm.live(), 0, "model leaked — mirror bug");
        assert_eq!(pool.charged_bytes(), (0, 0));
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
        assert_eq!(host.lock().unwrap().ledger.used(), 0);
    });
}

// ---------------------------------------------------------------------
// tensor ops
// ---------------------------------------------------------------------

#[test]
fn prop_slice_concat_roundtrip() {
    for_all("slice_concat", 200, |rng| {
        let rows = rng.range(1, 30);
        let cols = rng.range(1, 20);
        let t = rng.tensor(&[rows, cols]);
        let cut = rng.range(0, rows + 1);
        if cut == 0 || cut == rows {
            return;
        }
        let a = t.slice_rows(0, cut);
        let b = t.slice_rows(cut, rows);
        assert_eq!(Tensor::concat_rows(&[&a, &b]), t);
    });
}

#[test]
fn prop_zero_copy_views_match_seed_copying_semantics() {
    // slice_rows is now a zero-copy view and concat/pad assembly is a
    // single fused pass; both must stay bit-identical to the seed's
    // copy-based reference implementations.
    for_all("views_match_copies", 200, |rng| {
        let rows = rng.range(1, 24);
        let cols = rng.range(1, 16);
        let t = rng.tensor(&[rows, cols]);
        let lo = rng.range(0, rows);
        let hi = lo + rng.range(0, rows - lo + 1);
        let view = t.slice_rows(lo, hi);
        // seed reference: copy the row range out
        let want = Tensor::from_f32(
            t.as_f32()[lo * cols..hi * cols].to_vec(), &[hi - lo, cols]);
        assert_eq!(view, want, "slice_rows view != copied slice");

        // fused concat+pad vs the seed's two-pass reference
        let n_parts = rng.range(1, 5);
        let mut parts: Vec<Tensor> = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            let r = rng.range(1, 6);
            parts.push(rng.tensor(&[r, cols]));
        }
        let refs: Vec<&Tensor> = parts.iter().collect();
        let total: usize = parts.iter().map(|p| p.shape[0]).sum();
        let bucket = total + rng.range(0, 8);
        let fused = Tensor::concat_rows_padded(&refs, bucket);
        let mut seed = Vec::new();
        for p in &parts {
            seed.extend_from_slice(p.as_f32());
        }
        seed.resize(bucket * cols, 0.0);
        assert_eq!(fused, Tensor::from_f32(seed, &[bucket, cols]),
                   "fused assembly != concat_rows + pad_rows");
    });
}

#[test]
fn prop_copy_on_write_never_aliases_sibling_views() {
    for_all("cow_no_alias", 200, |rng| {
        let rows = rng.range(2, 16);
        let cols = rng.range(1, 12);
        let mut parent = rng.tensor(&[rows, cols]);
        let cut = rng.range(1, rows);
        let mut view_a = parent.slice_rows(0, cut);
        let view_b = parent.slice_rows(cut, rows);
        let clone = parent.clone();
        let snap_parent: Vec<f32> = parent.as_f32().to_vec();
        let snap_b: Vec<f32> = view_b.as_f32().to_vec();
        let snap_clone: Vec<f32> = clone.as_f32().to_vec();

        // mutate the first view through every mutating entry point
        let delta = rng.tensor(&[cut, cols]);
        ops::add_assign(&mut view_a, &delta);
        ops::add_scaled(&mut view_a, &delta, rng.f32());
        view_a.as_f32_mut()[0] += 1.0;
        assert_eq!(parent.as_f32(), &snap_parent[..],
                   "view mutation leaked into parent");
        assert_eq!(view_b.as_f32(), &snap_b[..],
                   "view mutation leaked into sibling view");

        // and mutate the parent: outstanding views/clones must hold
        let snap_a: Vec<f32> = view_a.as_f32().to_vec();
        parent.as_f32_mut()[rng.range(0, rows * cols)] = 42.0;
        assert_eq!(view_a.as_f32(), &snap_a[..],
                   "parent mutation leaked into view");
        assert_eq!(view_b.as_f32(), &snap_b[..],
                   "parent mutation leaked into view");
        assert_eq!(clone.as_f32(), &snap_clone[..],
                   "parent mutation leaked into clone");
    });
}

#[test]
fn prop_head_split_merge_roundtrip() {
    for_all("head_roundtrip", 200, |rng| {
        let nh = [1usize, 2, 4, 8][rng.range(0, 4)];
        let h = rng.range(1, 10);
        let t = rng.range(1, 20);
        let x = rng.tensor(&[t, nh * h]);
        assert_eq!(x.split_heads(nh).merge_heads(), x);
    });
}

#[test]
fn prop_pad_rows_preserves_prefix() {
    for_all("pad_rows", 200, |rng| {
        let rows = rng.range(1, 20);
        let cols = rng.range(1, 16);
        let x = rng.tensor(&[rows, cols]);
        let padded = x.pad_rows(rows + rng.range(0, 10));
        assert_eq!(&padded.as_f32()[..rows * cols], x.as_f32());
        for v in &padded.as_f32()[rows * cols..] {
            assert_eq!(*v, 0.0);
        }
    });
}

#[test]
fn prop_privacy_arithmetic_is_exact_for_linear() {
    // (x + n) W - nW == x W for arbitrary x, n, W (fp tolerance) —
    // the linearity that makes the noise protocol exact.
    for_all("privacy_linear", 100, |rng| {
        let t = rng.range(1, 10);
        let din = rng.range(1, 12);
        let dout = rng.range(1, 12);
        let x = rng.tensor(&[t, din]);
        let n = rng.tensor(&[t, din]);
        let w = rng.tensor(&[din, dout]);
        let noisy = ops::matmul(&ops::add(&x, &n), &w);
        let n_eff = ops::matmul(&n, &w);
        let recovered = ops::sub(&noisy, &n_eff);
        let want = ops::matmul(&x, &w);
        assert!(recovered.max_abs_diff(&want) < 1e-4);
    });
}

#[test]
fn prop_rmsnorm_bwd_matches_finite_difference() {
    for_all("rmsnorm_fd", 30, |rng| {
        let d = rng.range(2, 10);
        let x = rng.tensor(&[1, d]);
        let gain = rng.tensor(&[d]);
        let dy = rng.tensor(&[1, d]);
        let grad = ops::rmsnorm_bwd(&x, &gain, &dy);
        let eps = 1e-3f32;
        for i in 0..d {
            let mut xp = x.clone();
            xp.as_f32_mut()[i] += eps;
            let mut xm = x.clone();
            xm.as_f32_mut()[i] -= eps;
            let fd: f32 = ops::rmsnorm(&xp, &gain)
                .as_f32()
                .iter()
                .zip(ops::rmsnorm(&xm, &gain).as_f32())
                .zip(dy.as_f32())
                .map(|((p, m), g)| (p - m) / (2.0 * eps) * g)
                .sum();
            assert!((fd - grad.as_f32()[i]).abs() < 3e-2,
                    "d{i}: fd {fd} vs {}", grad.as_f32()[i]);
        }
    });
}

// ---------------------------------------------------------------------
// optimizer: native == artifact formula
// ---------------------------------------------------------------------

#[test]
fn prop_adam_native_monotone_moments() {
    for_all("adam_native", 100, |rng| {
        let n = rng.range(1, 50);
        let mut adam = Adam::new(n);
        let mut p: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let p0 = p.clone();
        let g: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        adam.step_native(&mut p, &g);
        for i in 0..n {
            if g[i] == 0.0 {
                assert_eq!(p[i], p0[i], "zero grad moved a param");
            } else {
                // step direction opposes gradient
                assert!((p0[i] - p[i]).signum() == g[i].signum()
                        || (p0[i] - p[i]).abs() < 1e-9);
            }
        }
    });
}

// ---------------------------------------------------------------------
// end-to-end randomized batching invariance (needs artifacts)
// ---------------------------------------------------------------------

#[test]
fn prop_executor_batching_matches_direct_execution() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use symbiosis::coordinator::proto::{LayerId, Urgency};
    use symbiosis::coordinator::{BatchPolicy, Deployment, Placement};
    let dep = Deployment::start(&symbiosis::config::SYM_TINY, &dir,
                                BatchPolicy::opportunistic_default(),
                                Placement::Local)
        .unwrap();
    let engine = dep.engine.clone();
    let weights = symbiosis::tensor::container::read_tensors(
        &dir.join("weights_sym-tiny.bin"))
        .unwrap();

    // random per-client token counts, concurrent submissions — each
    // client's result must equal a direct single-tensor execution.
    for_all("exec_batching", 5, |rng| {
        let n_clients = rng.range(2, 5);
        let mut handles = Vec::new();
        for _ in 0..n_clients {
            let t = rng.range(1, 24);
            let x = rng.tensor(&[t, 64]);
            let core = dep.client_core(None);
            let engine = engine.clone();
            let w = weights["l0.wqkv"].clone();
            let b = weights["l0.bqkv"].clone();
            handles.push(std::thread::spawn(move || {
                let got = core
                    .virt
                    .forward(LayerId::Qkv(0), x.clone(),
                             Urgency::Training)
                    .unwrap();
                // direct execution (unbatched) for comparison
                let bucket = bucket_for(t, TOKEN_BUCKETS).unwrap();
                let name = format!("linear_fwd_t{bucket}_64x192");
                let direct = engine
                    .execute(&name, &[&x.pad_rows(bucket), &w, &b])
                    .unwrap()[0]
                    .slice_rows(0, t);
                assert!(got.max_abs_diff(&direct) < 1e-4,
                        "batched != direct");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
}
