//! Full-system integration: Rust split execution must reproduce the jax
//! monolithic reference (goldens exported by `python/compile/aot.py`).
//!
//! This encodes the paper's central correctness claim: "the output with
//! Symbiosis is exactly identical to that of the baseline" — forward,
//! training gradients, optimizer updates, greedy generation, and the
//! privacy protocol all match, and cross-client batching does not change
//! any client's numerics.

use std::collections::HashMap;
use std::path::PathBuf;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::kv_cache::KvPlacement;
use symbiosis::coordinator::privacy::{NoiseGen, PrivacyCtx};
use symbiosis::coordinator::proto::{LayerId, Urgency};
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             InferenceSession, Placement, Trainer,
                             UrgencyPolicy};
use symbiosis::device::MemoryLedger;
use symbiosis::tensor::{container, Tensor};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

fn golden() -> HashMap<String, Tensor> {
    container::read_tensors(&artifact_dir().join("golden_sym-tiny.bin"))
        .unwrap()
}

fn start(policy: BatchPolicy) -> Deployment {
    Deployment::start(&SYM_TINY, &artifact_dir(), policy, Placement::Local)
        .unwrap()
}

fn lora8() -> Adapter {
    Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                 LoraTargets::QKVO, 2.0)
        .unwrap()
}

fn argmax_row(t: &Tensor, row: usize) -> i32 {
    let v = t.shape[1];
    let r = &t.as_f32()[row * v..(row + 1) * v];
    r.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32
}

#[test]
fn split_forward_matches_jax_monolith() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(None);
    let mut sess =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let first = sess.prefill(&tokens).unwrap();
    assert_eq!(first[0], argmax_row(&g["base_logits16"], 15));
    drop(sess);
    dep.shutdown();
}

#[test]
fn trainer_forward_loss_matches_golden() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(Some(lora8()));
    let mut tr = Trainer::new(core, 1).unwrap();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let labels: Vec<i32> = g["labels16"].as_i32().to_vec();
    let (loss, _grads) = tr.loss_and_grads(&tokens, &labels).unwrap();
    let want_loss = g["train_loss"].as_f32()[0];
    assert!((loss - want_loss).abs() < 1e-3,
            "loss {loss} vs golden {want_loss}");
    drop(tr);
    dep.shutdown();
}

#[test]
fn training_gradients_match_jax_autodiff() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(Some(lora8()));
    let mut tr = Trainer::new(core, 1).unwrap();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let labels: Vec<i32> = g["labels16"].as_i32().to_vec();
    let (_loss, grads) = tr.loss_and_grads(&tokens, &labels).unwrap();

    // flatten layout: layer-major, targets q,k,v,o, A then B
    let (d, r) = (64usize, 8usize);
    let mut off = 0;
    let mut max_diff = 0.0f32;
    for l in 0..SYM_TINY.n_layers {
        for t in ["q", "k", "v", "o"] {
            let ga = &g[&format!("grad.l{l}.{t}.a")];
            let gb = &g[&format!("grad.l{l}.{t}.b")];
            for (i, w) in ga.as_f32().iter().enumerate() {
                max_diff = max_diff.max((grads.flat[off + i] - w).abs());
            }
            off += d * r;
            for (i, w) in gb.as_f32().iter().enumerate() {
                max_diff = max_diff.max((grads.flat[off + i] - w).abs());
            }
            off += r * d;
        }
    }
    assert_eq!(off, grads.flat.len());
    assert!(max_diff < 5e-4, "max grad diff vs jax autodiff: {max_diff}");
    drop(tr);
    dep.shutdown();
}

#[test]
fn adam_update_matches_golden_step() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(Some(lora8()));
    let mut tr = Trainer::new(core, 1).unwrap();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let labels: Vec<i32> = g["labels16"].as_i32().to_vec();
    tr.train_step(&tokens, &labels).unwrap();
    let adapter = tr.core.adapter.as_ref().unwrap().flatten();
    let (d, r) = (64usize, 8usize);
    let mut off = 0;
    let mut max_diff = 0.0f32;
    for l in 0..SYM_TINY.n_layers {
        for t in ["q", "k", "v", "o"] {
            let pa = &g[&format!("step1.l{l}.{t}.a")];
            let pb = &g[&format!("step1.l{l}.{t}.b")];
            for (i, w) in pa.as_f32().iter().enumerate() {
                max_diff = max_diff.max((adapter[off + i] - w).abs());
            }
            off += d * r;
            for (i, w) in pb.as_f32().iter().enumerate() {
                max_diff = max_diff.max((adapter[off + i] - w).abs());
            }
            off += r * d;
        }
    }
    assert!(max_diff < 1e-3, "max adam diff: {max_diff}");
    drop(tr);
    dep.shutdown();
}

#[test]
fn greedy_generation_matches_jax() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(Some(lora8()));
    let mut sess =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    sess.prefill(&prompt).unwrap();
    for _ in 1..8 {
        sess.decode_step().unwrap();
    }
    let want: Vec<i32> = g["gen_tokens"].as_i32().to_vec();
    assert_eq!(sess.generated[0], want,
               "KV-cache decode diverged from jax full-recompute");
    drop(sess);
    dep.shutdown();
}

#[test]
fn bucket_padding_does_not_change_results() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let dep = start(BatchPolicy::NoLockstep);
    // 24 tokens pad to the 32 seq bucket and odd token buckets: the
    // result must still match jax (which never pads).
    let tokens: Vec<i32> = g["tokens24"].as_i32().to_vec();
    let core = dep.client_core(None);
    let mut sess =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    let first = sess.prefill(&tokens).unwrap();
    assert_eq!(first[0], argmax_row(&g["base_logits24"], 23));
    drop(sess);
    dep.shutdown();
}

#[test]
fn cross_client_batching_is_numerics_invariant() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let labels: Vec<i32> = g["labels16"].as_i32().to_vec();
    let want_loss = g["train_loss"].as_f32()[0];

    // 3 concurrent trainers sharing the executor with opportunistic
    // batching: every client must still get the exact single-client loss.
    let dep = start(BatchPolicy::opportunistic_default());
    let mut handles = Vec::new();
    for _ in 0..3 {
        let core = dep.client_core(Some(lora8()));
        let tokens = tokens.clone();
        let labels = labels.clone();
        handles.push(std::thread::spawn(move || {
            let mut tr = Trainer::new(core, 1).unwrap();
            let (loss, grads) =
                tr.loss_and_grads(&tokens, &labels).unwrap();
            (loss, grads.flat)
        }));
    }
    let results: Vec<(f32, Vec<f32>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (loss, _) in &results {
        assert!((loss - want_loss).abs() < 1e-3,
                "batched loss {loss} vs {want_loss}");
    }
    // all clients computed identical gradients (same data + adapter)
    for w in results.windows(2) {
        let max: f32 = w[0]
            .1
            .iter()
            .zip(&w[1].1)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max < 1e-4, "cross-client grad divergence {max}");
    }
    let stats = dep.shutdown();
    assert!(stats.requests_served > 0);
}

#[test]
fn privacy_protocol_is_exact_and_hides_activations() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();

    // Plain run.
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(Some(lora8()));
    let mut plain =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    plain.prefill(&prompt).unwrap();
    for _ in 1..8 {
        plain.decode_step().unwrap();
    }
    let want = plain.generated[0].clone();
    drop(plain);

    // Private run: register noise for every linear layer at the prefill
    // token count (decode iterations slice the leading row).
    let mut core = dep.client_core(Some(lora8()));
    let privacy = PrivacyCtx::new();
    let mut gen = NoiseGen::new(0xC0FFEE, 0.05);
    let tx = dep.executor.sender();
    let d = SYM_TINY.d_model;
    let f = SYM_TINY.d_ff;
    for l in 0..SYM_TINY.n_layers {
        for (layer, din) in [
            (LayerId::Qkv(l), d),
            (LayerId::AttnOut(l), d),
            (LayerId::MlpUp(l), d),
            (LayerId::MlpDown(l), f),
        ] {
            privacy
                .register_layer(&tx, layer, 8, din, &mut gen, 2)
                .unwrap();
        }
    }
    privacy
        .register_layer(&tx, LayerId::LmHead, 8, d, &mut gen, 2)
        .unwrap();
    {
        let virt = std::sync::Arc::get_mut(&mut core.virt).unwrap();
        virt.privacy = Some(privacy);
    }

    let mut private =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    private.prefill(&prompt).unwrap();
    for _ in 1..8 {
        private.decode_step().unwrap();
    }
    assert_eq!(private.generated[0], want,
               "privacy protocol changed the output");
    // the executor-facing log must show noised (not raw) activations
    let p = private.core.virt.privacy.as_ref().unwrap();
    assert!(!p.sent_log.lock().unwrap().is_empty());
    drop(private);
    dep.shutdown();
}

#[test]
fn incremental_prefill_equals_batch_prefill() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    let dep = start(BatchPolicy::NoLockstep);

    let core = dep.client_core(Some(lora8()));
    let mut a = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    a.prefill(&prompt).unwrap();
    for _ in 1..6 {
        a.decode_step().unwrap();
    }

    let core = dep.client_core(Some(lora8()));
    let mut b = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    b.prefill_incremental(&prompt).unwrap();
    for _ in 1..6 {
        b.decode_step().unwrap();
    }
    assert_eq!(a.generated[0], b.generated[0],
               "token-by-token prefill diverged from batch prefill");
    dep.shutdown();
}

#[test]
fn prefix_adapter_changes_output_and_decodes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    let dep = start(BatchPolicy::NoLockstep);

    // plain base model, incremental path
    let core = dep.client_core(None);
    let mut plain = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    plain.prefill_incremental(&prompt).unwrap();
    for _ in 1..6 {
        plain.decode_step().unwrap();
    }

    // prefix-tuned client: learned KV prefix seeds the cache
    let prefix = Adapter::prefix(&SYM_TINY, 1, 4, 99);
    let core = dep.client_core(Some(prefix));
    let mut tuned = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    tuned.seed_prefix().unwrap();
    tuned.prefill_incremental(&prompt).unwrap();
    for _ in 1..6 {
        tuned.decode_step().unwrap();
    }
    assert_eq!(tuned.generated[0].len(), plain.generated[0].len());
    assert_ne!(tuned.generated[0], plain.generated[0],
               "a non-trivial prefix must change the distribution");
    dep.shutdown();
}

#[test]
fn ia3_adapter_serves_and_differs_from_base() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    let dep = start(BatchPolicy::NoLockstep);

    let core = dep.client_core(None);
    let mut base = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    base.prefill(&prompt).unwrap();

    // identity IA3 == base model exactly
    let core = dep.client_core(Some(Adapter::ia3(&SYM_TINY)));
    let mut ident = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    ident.prefill(&prompt).unwrap();
    assert_eq!(base.generated[0], ident.generated[0]);

    // perturbed IA3 (v and ff rescaled) changes the decoded sequence
    let mut ia3 = Adapter::ia3(&SYM_TINY);
    if let Adapter::Ia3(a) = &mut ia3 {
        for t in a.v_scale.iter_mut().chain(a.ff_scale.iter_mut()) {
            for (i, v) in t.as_f32_mut().iter_mut().enumerate() {
                *v = if i % 2 == 0 { 1.6 } else { 0.5 };
            }
        }
    }
    let core = dep.client_core(Some(ia3));
    let mut tuned = InferenceSession::new(core, 1, KvPlacement::Device)
        .unwrap();
    tuned.prefill(&prompt).unwrap();
    for _ in 1..6 {
        tuned.decode_step().unwrap();
        base.decode_step().unwrap();
    }
    assert_ne!(base.generated[0], tuned.generated[0]);
    dep.shutdown();
}

#[test]
fn trainer_rejects_inference_only_adapters() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(Some(Adapter::ia3(&SYM_TINY)));
    assert!(Trainer::new(core, 1).is_err());
    let core = dep.client_core(None);
    assert!(Trainer::new(core, 1).is_err());
    dep.shutdown();
}

#[test]
fn unsupported_batch_size_is_rejected() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start(BatchPolicy::NoLockstep);
    let core = dep.client_core(None);
    // batch 3 has no attention artifact (exported: 1, 2, 4)
    assert!(InferenceSession::new(core, 3, KvPlacement::Device).is_err());
    dep.shutdown();
}

#[test]
fn executor_survives_client_churn() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    let dep = start(BatchPolicy::opportunistic_default());
    // waves of clients appearing and vanishing (deregistration on drop)
    for _wave in 0..3 {
        let mut handles = Vec::new();
        for _ in 0..3 {
            let core = dep.client_core(None);
            let prompt = prompt.clone();
            handles.push(std::thread::spawn(move || {
                let mut s = InferenceSession::new(
                    core, 1, KvPlacement::Device).unwrap();
                s.prefill(&prompt).unwrap();
                s.decode_step().unwrap();
                s.generated[0].clone()
            }));
        }
        let first: Vec<Vec<i32>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // same prompt, same base model => identical outputs every wave
        assert!(first.windows(2).all(|w| w[0] == w[1]));
    }
    let stats = dep.shutdown();
    assert!(stats.requests_served > 0);
}

#[test]
fn sym_small_generality_forward_and_generation() {
    // The second executable config (8 layers, d=128, 8 heads) proves the
    // split-execution machinery is not specialized to one model shape —
    // the paper's model-transparency goal (section 3.1, goal 3).
    use symbiosis::config::SYM_SMALL;
    let dir = artifact_dir();
    if !dir.join("golden_sym-small.bin").exists() {
        eprintln!("skipping: sym-small artifacts not built");
        return;
    }
    let g = container::read_tensors(&dir.join("golden_sym-small.bin"))
        .unwrap();
    let dep = Deployment::start(&SYM_SMALL, &dir,
                                BatchPolicy::NoLockstep,
                                Placement::Local)
        .unwrap();
    // forward matches the jax monolith
    let core = dep.client_core(None);
    let mut sess =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let first = sess.prefill(&tokens).unwrap();
    assert_eq!(first[0], argmax_row(&g["base_logits16"], 15));
    drop(sess);

    // LoRA generation matches jax full-recompute decoding
    let adapter = Adapter::lora_from_artifacts(
        &SYM_SMALL, &dir, 8, LoraTargets::QKVO, 2.0).unwrap();
    let core = dep.client_core(Some(adapter));
    let mut sess =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    sess.prefill(&prompt).unwrap();
    for _ in 1..8 {
        sess.decode_step().unwrap();
    }
    let want: Vec<i32> = g["gen_tokens"].as_i32().to_vec();
    assert_eq!(sess.generated[0], want);
    drop(sess);
    dep.shutdown();
}

#[test]
fn sym_small_training_matches_jax() {
    use symbiosis::config::SYM_SMALL;
    let dir = artifact_dir();
    if !dir.join("golden_sym-small.bin").exists() {
        eprintln!("skipping: sym-small artifacts not built");
        return;
    }
    let g = container::read_tensors(&dir.join("golden_sym-small.bin"))
        .unwrap();
    let dep = Deployment::start(&SYM_SMALL, &dir,
                                BatchPolicy::NoLockstep,
                                Placement::Local)
        .unwrap();
    let adapter = Adapter::lora_from_artifacts(
        &SYM_SMALL, &dir, 8, LoraTargets::QKVO, 2.0).unwrap();
    let core = dep.client_core(Some(adapter));
    let mut tr = Trainer::new(core, 1).unwrap();
    let tokens: Vec<i32> = g["tokens16"].as_i32().to_vec();
    let labels: Vec<i32> = g["labels16"].as_i32().to_vec();
    let (loss, grads) = tr.loss_and_grads(&tokens, &labels).unwrap();
    let want = g["train_loss"].as_f32()[0];
    assert!((loss - want).abs() < 1e-3, "loss {loss} vs {want}");
    // spot-check gradient block 0 against jax autodiff
    let ga = &g["grad.l0.q.a"];
    let mut max_diff = 0.0f32;
    for (i, w) in ga.as_f32().iter().enumerate() {
        max_diff = max_diff.max((grads.flat[i] - w).abs());
    }
    assert!(max_diff < 5e-4, "grad diff {max_diff}");
    drop(tr);
    dep.shutdown();
}

// ---------------------------------------------------------------------------
// Paged KV cache: prefix sharing and ledger-backed swap (PR 9)
// ---------------------------------------------------------------------------

fn kv_charged(dep: &Deployment) -> u64 {
    dep.client_device.lock().unwrap().ledger.prefix_bytes("kv:")
}

/// A session adopting a published KV prefix must generate exactly the
/// tokens a session that prefilled the full prompt generates — and the
/// adoption itself must charge the device ledger nothing (the
/// publisher's blocks are mapped, not copied).
#[test]
fn adopted_kv_prefix_generates_identically_and_charges_nothing() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();
    let (prefix, suffix) = prompt.split_at(prompt.len() / 2);
    let dep = start(BatchPolicy::NoLockstep);

    // baseline: one session pays the full prompt
    let mut base = dep.session().build().unwrap();
    base.prefill(&prompt).unwrap();
    for _ in 1..8 {
        base.decode_step().unwrap();
    }
    let want = base.generated[0].clone();
    drop(base);

    // publisher prefills only the shared prefix and publishes it
    let mut publ = dep.session().build().unwrap();
    publ.prefill(prefix).unwrap();
    assert!(publ.publish_kv_prefix("sys", prefix).unwrap(),
            "first publish must take the key");
    let before = kv_charged(&dep);
    assert!(before > 0, "publisher's prefix must be charged");

    // two adopters map the same blocks; each pays only its suffix
    let mut adopters = Vec::new();
    for _ in 0..2 {
        let mut s = dep
            .session()
            .adopt_kv_prefix("sys")
            .build()
            .unwrap();
        assert_eq!(kv_charged(&dep), before,
                   "adoption itself must not charge the device");
        s.prefill_incremental(suffix).unwrap();
        for _ in 1..8 {
            s.decode_step().unwrap();
        }
        assert_eq!(s.generated[0], want,
                   "adopter diverged from full-prompt prefill");
        adopters.push(s);
    }
    drop(adopters);
    drop(publ);
    dep.shutdown();
}

/// Acceptance: 8 sessions sharing a 256-token prefix charge the device
/// ledger less than 2x what one session charges.
#[test]
fn eight_sessions_sharing_a_long_prefix_charge_less_than_two() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start(BatchPolicy::NoLockstep);
    let prefix: Vec<i32> =
        (0..256).map(|i| ((i * 7 + 3) % 256) as i32).collect();
    let suffix: Vec<i32> = (0..16).map(|i| (i % 256) as i32).collect();

    let mut publ = dep.session().build().unwrap();
    publ.prefill(&prefix).unwrap();
    assert!(publ.publish_kv_prefix("doc", &prefix).unwrap());
    let one = kv_charged(&dep);

    let mut sessions = Vec::new();
    for _ in 0..7 {
        let mut s = dep
            .session()
            .adopt_kv_prefix("doc")
            .build()
            .unwrap();
        s.prefill_incremental(&suffix).unwrap();
        sessions.push(s);
    }
    let total = kv_charged(&dep);
    assert!(total < 2 * one,
            "8 sessions over a shared 256-token prefix charged {total} \
             bytes, >= 2x one session's {one}");
    drop(sessions);
    drop(publ);
    assert_eq!(kv_charged(&dep), 0, "drained sessions left KV charged");
    dep.shutdown();
}

/// Acceptance: an append that would fire `KvCacheOom` instead swaps a
/// background session's cold blocks to the host; both sessions finish
/// token-identically to an unconstrained run, and the swap shows up in
/// `FleetStats`.
#[test]
fn kv_swap_rescues_foreground_and_counts_in_fleet_stats() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = golden();
    let prompt: Vec<i32> = g["gen_prompt"].as_i32().to_vec();

    // reference: unconstrained device
    let dep0 = start(BatchPolicy::NoLockstep);
    let mut r = dep0.session().build().unwrap();
    r.prefill(&prompt).unwrap();
    for _ in 1..8 {
        r.decode_step().unwrap();
    }
    let want = r.generated[0].clone();
    drop(r);
    dep0.shutdown();

    // constrained device: room for one session's blocks plus one more
    // block — the second prefill must displace the background session
    let dep = start(BatchPolicy::NoLockstep);
    let block: u64 = 2 * 4 * 16 * 16 * 4; // bh=4, 16 tokens, h=16, f32
    dep.client_device.lock().unwrap().ledger =
        MemoryLedger::new(5 * block);

    let mut bg = dep
        .session()
        .urgency(UrgencyPolicy {
            prefill: Urgency::Background,
            decode: Urgency::Background,
        })
        .build()
        .unwrap();
    bg.prefill(&prompt).unwrap(); // one block per layer: 4 blocks

    let mut fg = dep.session().build().unwrap();
    fg.prefill(&prompt).unwrap(); // needs 4 blocks, only 1 is free
    for _ in 1..8 {
        fg.decode_step().unwrap();
    }
    assert_eq!(fg.generated[0], want, "foreground diverged under swap");
    assert!(dep.kv_pool.swap_stats().swap_outs > 0,
            "foreground prefill did not swap background blocks");

    // the background session faults its blocks back in and finishes
    // with identical tokens
    drop(fg);
    for _ in 1..8 {
        bg.decode_step().unwrap();
    }
    assert_eq!(bg.generated[0], want,
               "background tokens corrupted by swap round-trip");
    drop(bg);
    let stats = dep.shutdown();
    assert!(stats.kv_swap_outs > 0, "swap-outs missing from FleetStats");
    assert!(stats.kv_fault_ins > 0, "fault-ins missing from FleetStats");
    assert_eq!(stats.kv_swapped_blocks, 0,
               "all swapped blocks should have faulted back or freed");
}
