//! Fleet equivalence: sharding the executor must change *where* layers
//! run, never *what* they compute.
//!
//! The acceptance bar for the sharded fleet (ISSUE 4): generation is
//! bit-identical across shard counts for every adapter kind, a trainer's
//! loss trajectory matches across shard counts, each shard's device
//! ledger carries its real slice of the base (~1/N plus boundary
//! tables), an undeployable plan fails with a typed OOM before any
//! thread spawns, and a client dropping mid-run under lockstep neither
//! wedges the survivors nor the fleet shutdown.
//!
//! Tests skip when artifacts are absent (same convention as
//! `integration.rs`).

use std::path::PathBuf;
use std::sync::Arc;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::fleet::ExecutorFleet;
use symbiosis::coordinator::model_state;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             GenerationConfig, Placement, SymbiosisError};
use symbiosis::device::{Device, DeviceKind, MemoryLedger};
use symbiosis::runtime::Engine;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

/// One engine (compile cache) shared by every deployment in this file.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(&artifact_dir()).unwrap()))
        .clone()
}

/// Deploy over `shards` executor shards (1 = the pre-fleet topology).
fn deploy(shards: usize, policy: BatchPolicy) -> Deployment {
    let placement = if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  policy, placement)
        .unwrap()
}

fn lora8() -> Adapter {
    Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                 LoraTargets::QKVO, 2.0)
        .unwrap()
}

fn prompt() -> Vec<i32> {
    (0..16).map(|i| (i * 7 + 3) as i32 % 256).collect()
}

/// Greedy generation for one adapter kind on an n-shard fleet.
fn generate_on(shards: usize, adapter: Option<Adapter>) -> Vec<Vec<i32>> {
    let dep = deploy(shards, BatchPolicy::NoLockstep);
    let mut b = dep.session();
    if let Some(a) = adapter {
        b = b.adapter(a);
    }
    let mut sess = b.build().unwrap();
    let out = sess
        .generate(&prompt(), &GenerationConfig::greedy(12))
        .unwrap();
    drop(sess);
    dep.shutdown();
    out
}

#[test]
fn generation_is_bit_identical_across_shard_counts() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // SYM_TINY has 4 blocks: shards=2 and shards=4 exercise both the
    // multi-block and one-block-per-shard partitions.
    let adapters: Vec<(&str, fn() -> Option<Adapter>)> = vec![
        ("base", || None),
        ("lora", || Some(lora8())),
        ("ia3", || Some(Adapter::ia3(&SYM_TINY))),
        ("prefix", || Some(Adapter::prefix(&SYM_TINY, 1, 4, 11))),
    ];
    for (label, mk) in adapters {
        let golden = generate_on(1, mk());
        for shards in [2usize, 4] {
            let got = generate_on(shards, mk());
            assert_eq!(got, golden,
                       "{label}: shards={shards} diverged from shards=1");
        }
    }
}

#[test]
fn trainer_loss_trajectory_matches_across_shard_counts() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = |shards: usize| -> Vec<f32> {
        let dep = deploy(shards, BatchPolicy::NoLockstep);
        let mut tr = dep
            .trainer()
            .adapter(lora8())
            .lr(5e-3)
            .build()
            .unwrap();
        let tokens: Vec<i32> =
            (0..16).map(|i| (i * 5 + 1) as i32 % 256).collect();
        let labels: Vec<i32> =
            (0..16).map(|i| (i * 5 + 2) as i32 % 256).collect();
        let losses: Vec<f32> = (0..4)
            .map(|_| tr.train_step(&tokens, &labels).unwrap().loss)
            .collect();
        drop(tr);
        dep.shutdown();
        losses
    };
    let golden = run(1);
    assert!(golden.windows(2).any(|w| w[1] != w[0]),
            "degenerate trajectory: {golden:?}");
    for shards in [2usize, 4] {
        assert_eq!(run(shards), golden,
                   "loss trajectory diverged at shards={shards}");
    }
}

#[test]
fn shard_ledgers_carry_real_base_slices() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (base, _) =
        model_state::load_split(&SYM_TINY, &artifact_dir()).unwrap();
    let total = base.param_bytes();
    // Boundary tables (embed + pos on shard 0, LM head on the last
    // shard) ride outside the even 1/N block split.
    let boundary = (base.embed.size_bytes() + base.pos.size_bytes()
        + base.lm_head_w.size_bytes()
        + base.lm_head_b.size_bytes()) as u64;
    drop(base);
    for shards in [2usize, 4] {
        let dep = deploy(shards, BatchPolicy::NoLockstep);
        let resident = dep.executor.shard_resident_bytes();
        assert_eq!(resident.len(), shards);
        // conservation: the slices are the base, nothing more or less
        assert_eq!(resident.iter().sum::<u64>(), total);
        for (s, r) in resident.iter().enumerate() {
            assert!(*r > 0, "shard {s} holds nothing");
            assert!(*r <= total / shards as u64 + boundary,
                    "shard {s} resident {r} exceeds 1/{shards} of \
                     {total} plus boundary tables {boundary}");
        }
        dep.shutdown();
    }
}

#[test]
fn undeployable_plan_fails_with_typed_oom() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (base, _) =
        model_state::load_split(&SYM_TINY, &artifact_dir()).unwrap();
    // Two devices whose ledgers cannot hold half the base each: the
    // fleet must refuse to start (same charge path `Deployment::start`
    // runs), with the failing shard in the error.
    let devices: Vec<Device> = (0..2)
        .map(|s| {
            let mut d =
                Device::new(&format!("tiny{s}"), DeviceKind::GpuFast40);
            d.ledger = MemoryLedger::new(16 * 1024);
            d
        })
        .collect();
    let err = ExecutorFleet::start_with_devices(
        engine(), base, BatchPolicy::NoLockstep, devices)
        .unwrap_err();
    match SymbiosisError::from(err) {
        SymbiosisError::ShardOom { shard, need_bytes,
                                   capacity_bytes } => {
            assert_eq!(shard, 0);
            assert_eq!(capacity_bytes, 16 * 1024);
            assert!(need_bytes > capacity_bytes);
        }
        other => panic!("expected ShardOom, got {other}"),
    }
}

/// Satellite: a client dropping mid-run while a lockstep barrier is
/// pending must not wedge the remaining clients (the Drop-deregister
/// reaches every shard; the safety cap bounds the stall) nor the fleet
/// shutdown drain.
#[test]
fn client_churn_under_lockstep_makes_progress() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2, BatchPolicy::Lockstep);
    let mut survivor = dep.session().build().unwrap();
    let mut leaver = dep.session().build().unwrap();

    // Both clients prefill: the lockstep barrier sees 2 registered
    // clients at each shard and batches them together.
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let out = survivor
            .generate(&prompt(), &GenerationConfig::greedy(6))
            .map(|g| g[0].len());
        let _ = done_tx.send(());
        out
    });
    // The leaver joins one layer round, then drops mid-run with the
    // survivor's barrier pending.
    leaver.prefill(&prompt()).unwrap();
    drop(leaver);

    // The survivor must finish well within the lockstep safety cap
    // (50 ms per layer worst case, ~18 layer calls per step).
    done_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("survivor wedged after client churn under lockstep");
    let generated = handle.join().unwrap().unwrap();
    assert_eq!(generated, 6, "survivor truncated its generation");

    // Fleet shutdown drains both shards cleanly after the churn.
    let stats = dep.shutdown();
    assert_eq!(stats.n_shards(), 2);
    assert!(stats.n_flushes > 0);
    assert!(stats.requests_served > 0);
}
