//! Golden equivalence: the session-first API must emit byte-identical
//! tokens to the pre-redesign low-level loop, for every adapter kind.
//!
//! These tests pin the two core claims of the API redesign:
//! * `Session::generate` (builder path) == the caller-managed
//!   `prefill` + `decode_step` loop, per adapter (none/LoRA/IA3/Prefix).
//! * The shared `LayerWalker`'s batch-prefill attention == its
//!   incremental (decode-path) prefill, so the one-block implementation
//!   is self-consistent across its two attention modes.
//!
//! Plus the prefix-adapter footgun: batch prefill on a seeded cache is a
//! typed hard error, and the builder's auto-routing avoids it.

use std::path::PathBuf;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             GenerationConfig, InferenceSession,
                             KvPlacement, Placement, Sampling,
                             SymbiosisError};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

fn start() -> Deployment {
    Deployment::start(&SYM_TINY, &artifact_dir(),
                      BatchPolicy::NoLockstep, Placement::Local)
        .unwrap()
}

fn lora8() -> Adapter {
    Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                 LoraTargets::QKVO, 2.0)
        .unwrap()
}

fn perturbed_ia3() -> Adapter {
    let mut ia3 = Adapter::ia3(&SYM_TINY);
    if let Adapter::Ia3(a) = &mut ia3 {
        for t in a.v_scale.iter_mut().chain(a.ff_scale.iter_mut()) {
            for (i, v) in t.as_f32_mut().iter_mut().enumerate() {
                *v = if i % 2 == 0 { 1.4 } else { 0.6 };
            }
        }
    }
    ia3
}

fn prompt(len: usize, batch: usize) -> Vec<i32> {
    (0..len * batch).map(|i| (i * 7 % 256) as i32).collect()
}

/// Pre-redesign usage: construct the session by hand, drive the loop by
/// hand (seed + incremental prefill for prefix adapters, batch prefill
/// otherwise).
fn old_loop_tokens(dep: &Deployment, adapter: Option<Adapter>,
                   gen_len: usize) -> Vec<i32> {
    let is_prefix = matches!(adapter, Some(Adapter::Prefix(_)));
    let core = dep.client_core(adapter);
    let mut sess =
        InferenceSession::new(core, 1, KvPlacement::Device).unwrap();
    let p = prompt(8, 1);
    if is_prefix {
        sess.seed_prefix().unwrap();
        sess.prefill_incremental(&p).unwrap();
    } else {
        sess.prefill(&p).unwrap();
    }
    for _ in 1..gen_len {
        sess.decode_step().unwrap();
    }
    sess.generated[0].clone()
}

#[test]
fn generate_matches_old_loop_for_every_adapter_kind() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    let gen_len = 8;
    let cases: Vec<(&str, Option<Adapter>)> = vec![
        ("none", None),
        ("lora", Some(lora8())),
        ("ia3", Some(perturbed_ia3())),
        ("prefix", Some(Adapter::prefix(&SYM_TINY, 1, 4, 99))),
    ];
    for (name, adapter) in cases {
        let want = old_loop_tokens(&dep, adapter.clone(), gen_len);
        let mut b = dep.session();
        if let Some(a) = adapter {
            b = b.adapter(a);
        }
        let mut sess = b.build().unwrap();
        let out = sess
            .generate(&prompt(8, 1), &GenerationConfig::greedy(gen_len))
            .unwrap();
        assert_eq!(out[0], want,
                   "generate() diverged from the old loop for {name}");
        assert_eq!(out[0].len(), gen_len);
    }
    dep.shutdown();
}

#[test]
fn generate_matches_old_loop_for_batched_requests() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    let (batch, gen_len) = (2usize, 6usize);
    let p = prompt(8, batch);

    let core = dep.client_core(Some(lora8()));
    let mut old =
        InferenceSession::new(core, batch, KvPlacement::Device).unwrap();
    old.prefill(&p).unwrap();
    for _ in 1..gen_len {
        old.decode_step().unwrap();
    }

    let mut new = dep.session()
        .adapter(lora8())
        .batch(batch)
        .build()
        .unwrap();
    let out =
        new.generate(&p, &GenerationConfig::greedy(gen_len)).unwrap();
    assert_eq!(out, old.generated);
    dep.shutdown();
}

#[test]
fn walker_batch_prefill_equals_incremental_prefill() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    for batch in [1usize, 2] {
        let p = prompt(8, batch);
        let mut a = dep.session().batch(batch).build().unwrap();
        a.prefill(&p).unwrap();
        let mut b = dep.session().batch(batch).build().unwrap();
        b.prefill_incremental(&p).unwrap();
        for _ in 0..4 {
            a.decode_step().unwrap();
            b.decode_step().unwrap();
        }
        assert_eq!(a.generated, b.generated,
                   "walker prefill modes diverged at batch {batch}");
    }
    dep.shutdown();
}

#[test]
fn batch_prefill_on_seeded_cache_is_a_hard_error() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    let mut sess = dep.session()
        .adapter(Adapter::prefix(&SYM_TINY, 1, 4, 99))
        .build()
        .unwrap();
    // the builder seeded the prefix: the fast bucketed prefill would
    // silently ignore those cache rows — must be refused, not computed
    let err = sess.prefill(&prompt(8, 1)).unwrap_err();
    assert!(
        matches!(err,
                 SymbiosisError::PrefilledCacheNeedsIncremental {
                     cached_rows: 4,
                 }),
        "expected the prefix footgun error, got: {err}"
    );
    // ... while the routed paths still serve the request
    let first = sess.prefill_auto(&prompt(8, 1)).unwrap();
    assert_eq!(first.len(), 1);
    dep.shutdown();
}

#[test]
fn prefix_sessions_auto_seed_and_differ_from_base() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    let cfg = GenerationConfig::greedy(6);
    let mut base = dep.session().build().unwrap();
    let base_out = base.generate(&prompt(8, 1), &cfg).unwrap();
    let mut tuned = dep.session()
        .adapter(Adapter::prefix(&SYM_TINY, 1, 4, 99))
        .build()
        .unwrap();
    // no manual seed_prefix() call — the builder did it
    let tuned_out = tuned.generate(&prompt(8, 1), &cfg).unwrap();
    assert_eq!(tuned_out[0].len(), base_out[0].len());
    assert_ne!(tuned_out[0], base_out[0],
               "a non-trivial prefix must change the distribution");
    dep.shutdown();
}

#[test]
fn generate_honors_stop_tokens_and_max_tokens() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    // learn the greedy continuation, then stop on its second token
    let mut probe = dep.session().build().unwrap();
    let full = probe
        .generate(&prompt(8, 1), &GenerationConfig::greedy(6))
        .unwrap()[0]
        .clone();
    assert_eq!(full.len(), 6);

    let mut sess = dep.session().build().unwrap();
    let cfg = GenerationConfig::greedy(6).with_stop(full[1]);
    let out = sess.generate(&prompt(8, 1), &cfg).unwrap();
    assert_eq!(out[0], full[..2].to_vec(),
               "generation must stop right after the stop token");
    dep.shutdown();
}

#[test]
fn sampled_generation_is_deterministic_per_seed() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    let cfg = GenerationConfig {
        max_tokens: 6,
        stop_tokens: Vec::new(),
        sampling: Sampling::TopK { k: 8, temperature: 0.9, seed: 1234 },
        prefill_chunk: None,
    };
    let mut a = dep.session().build().unwrap();
    let mut b = dep.session().build().unwrap();
    let out_a = a.generate(&prompt(8, 1), &cfg).unwrap();
    let out_b = b.generate(&prompt(8, 1), &cfg).unwrap();
    assert_eq!(out_a, out_b, "same seed must replay the same stream");
    assert_eq!(out_a[0].len(), 6);
    dep.shutdown();
}

#[test]
fn builders_surface_typed_errors() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = start();
    // batch 3 has no attention artifact (exported: 1, 2, 4)
    let Err(err) = dep.session().batch(3).build() else {
        panic!("batch 3 must be rejected");
    };
    assert!(matches!(err,
                     SymbiosisError::UnsupportedBatch { batch: 3, .. }));
    // IA3 / missing adapters are not trainable
    let Err(err) =
        dep.trainer().adapter(Adapter::ia3(&SYM_TINY)).build()
    else {
        panic!("IA3 trainer must be rejected");
    };
    assert!(matches!(err, SymbiosisError::NotTrainable { .. }));
    let Err(err) = dep.trainer().build() else {
        panic!("adapter-less trainer must be rejected");
    };
    assert!(matches!(err, SymbiosisError::NotTrainable { .. }));
    // a prefix built for batch 1 cannot seed a batch-2 session
    let Err(err) = dep.session()
        .adapter(Adapter::prefix(&SYM_TINY, 1, 4, 99))
        .batch(2)
        .build()
    else {
        panic!("mismatched prefix batch must be rejected");
    };
    assert!(matches!(err, SymbiosisError::PrefixBatchMismatch { .. }));
    // decode before prefill
    let mut sess = dep.session().build().unwrap();
    let err = sess.decode_step().unwrap_err();
    assert!(matches!(err, SymbiosisError::DecodeBeforePrefill));
    dep.shutdown();
}
