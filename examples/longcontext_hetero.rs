//! Long-context inference with heterogeneous compute (paper section
//! 3.4, Figs. 19/20).
//!
//! Two parts:
//! 1. **Real run** on sym-tiny: a CPU-placed client with a
//!    host-offloaded KV cache decodes against growing context; we report
//!    measured per-token latency and the cache/transfer accounting.
//! 2. **Analytic reproduction of Fig. 19** on Llama2-7B: inter-token
//!    latency vs context length for (a) all-GPU, (b) GPU compute +
//!    CPU-offloaded cache, (c) Symbiosis CPU-client — showing the
//!    crossover where shipping the KV cache over PCIe costs more than
//!    computing attention on the CPU, and the OOM walls.
//!
//! Run:  cargo run --release --example longcontext_hetero

use std::path::PathBuf;
use std::time::Instant;

use symbiosis::config::{LLAMA2_7B, SYM_TINY};
use symbiosis::coordinator::{BatchPolicy, Deployment, KvPlacement,
                             Placement};
use symbiosis::device::{Device, DeviceKind, GIB};
use symbiosis::transport::LinkKind;

fn main() -> anyhow::Result<()> {
    real_tiny_run()?;
    analytic_fig19();
    Ok(())
}

fn real_tiny_run() -> anyhow::Result<()> {
    let artifact_dir =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifact_dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("== Part 1: real CPU-client decode on {} ==", SYM_TINY.name);
    let dep = Deployment::start(&SYM_TINY, &artifact_dir,
                                BatchPolicy::NoLockstep,
                                Placement::CpuClient)?;
    let mut sess = dep.session().kv(KvPlacement::Host).build()?;
    let prompt: Vec<i32> = (0..64).map(|i| (i * 5 % 256) as i32).collect();
    sess.prefill(&prompt)?;
    println!("prefill done: kv cache {} tokens, {} KiB (host-offloaded)",
             sess.kv_len(), sess.kv_bytes() / 1024);
    println!("\n{:>8} {:>14} {:>18}", "context", "ms/token",
             "KV transfer/step");
    for chunk in 0..6 {
        let t0 = Instant::now();
        let n = 16;
        for _ in 0..n {
            sess.decode_step()?;
        }
        let per_tok = t0.elapsed().as_secs_f64() * 1e3 / n as f64;
        println!("{:>8} {:>14.2} {:>15} KiB", sess.kv_len(), per_tok,
                 sess.kv_transfer_bytes_per_step() / 1024);
        let _ = chunk;
    }
    dep.shutdown();
    Ok(())
}

/// Fig. 19 reproduction: inter-token latency vs context length for
/// Llama2-7B under the three systems, from the device + link models.
///
/// Calibration (documented in DESIGN.md section 3): the offload baseline
/// overlaps per-layer cache transfers with prefetch (HF OffloadedCache),
/// so it pays the PCIe stream at full 25 GB/s; the Symbiosis CPU client
/// computes attention on the host at an *effective* 50 GB/s (attention
/// is DRAM-bandwidth-bound, torch-CPU efficiency ~25%) plus a constant
/// per-token CPU framework overhead — which is why the paper's Fig 19
/// shows the baseline winning below ~32K and Symbiosis winning beyond
/// ("33% faster at 64K, constant CPU-GPU transfer regardless of cache").
fn analytic_fig19() {
    println!("\n== Part 2: Fig. 19 (Llama2-7B inter-token latency) ==");
    let cfg = &LLAMA2_7B;
    let gpu = Device::new("a100", DeviceKind::GpuA100_80);
    // effective rates (see doc comment)
    const PCIE_EFF: f64 = 25e9;
    const CPU_ATTN_EFF: f64 = 50e9;
    const CPU_CLIENT_CONST: f64 = 0.32; // s/token framework overhead
    // the paper's all-GPU baseline fails beyond a 16 GiB cache (weights
    // + activations + fragmentation leave ~16 GiB for KV on the 80 GiB
    // card in their harness)
    const GPU_KV_BUDGET: u64 = 16 * GIB;

    println!("{:>10} {:>12} {:>16} {:>14}", "context", "all-GPU",
             "GPU+offload-KV", "Symbiosis-CPU");
    let mut crossover: Option<u64> = None;
    for log2 in 12..=17 {
        let ctx: u64 = 1 << log2; // 4K .. 128K
        let kv_bytes = cfg.kv_cache_bytes(1, ctx as usize);
        let linear_flops = cfg.forward_flops(1, 0);
        let attn_flops = 4 * cfg.n_layers as u64 * ctx
            * cfg.d_model as u64;
        let t_gpu_compute = gpu.op_time(linear_flops + attn_flops,
                                        kv_bytes.min(GPU_KV_BUDGET)
                                            + cfg.param_bytes() / 64,
                                        cfg.precision);

        // (a) all-GPU
        let all_gpu = if kv_bytes <= GPU_KV_BUDGET {
            format!("{:.1} ms", t_gpu_compute * 1e3)
        } else {
            "OOM".to_string()
        };

        // (b) KV on host, compute on GPU: stream the cache each step
        let t_offload = t_gpu_compute + kv_bytes as f64 / PCIE_EFF;

        // (c) Symbiosis CPU client: linears on GPU, attention on host,
        // constant activation traffic across PCIe
        let xfer = LinkKind::Pcie.transfer_time(
            (cfg.n_layers as u64 * 4 + 2) * 2 * cfg.activation_bytes(1));
        let t_sym = gpu.op_time(linear_flops, cfg.param_bytes() / 64,
                                cfg.precision)
            + CPU_CLIENT_CONST
            + kv_bytes as f64 / CPU_ATTN_EFF
            + xfer;
        if t_sym < t_offload && crossover.is_none() {
            crossover = Some(ctx);
        }
        println!("{:>9}K {:>12} {:>13.1} ms {:>11.1} ms  (KV {:.0} GiB)",
                 ctx / 1024, all_gpu, t_offload * 1e3, t_sym * 1e3,
                 kv_bytes as f64 / GIB as f64);
    }
    if let Some(c) = crossover {
        println!("\ncrossover: Symbiosis CPU-client wins from {}K \
                  context (paper: 32K), and is ~25-33% faster at 64K; \
                  the all-GPU baseline OOMs past a 16 GiB cache while \
                  Symbiosis scales to 128K.", c / 1024);
    }
}
