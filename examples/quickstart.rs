//! Quickstart: serve a small real model to multiple adapter clients.
//!
//! The end-to-end serving driver: loads the AOT-compiled `sym-tiny`
//! model, starts one shared base executor, attaches four inference
//! tenants with *different* adapters (LoRA r=8, LoRA r=64, IA3, and the
//! plain base model) through the session-first builder API, serves
//! batched requests concurrently, and reports per-client latency plus
//! aggregate throughput and executor batching statistics.  Results are
//! recorded in EXPERIMENTS.md.
//!
//! Run:  cargo run --release --example quickstart
//! (requires `make artifacts` first)

use std::path::PathBuf;
use std::time::Instant;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             GenerationConfig, Placement};
use symbiosis::metrics::LatencyStats;

fn main() -> anyhow::Result<()> {
    let artifact_dir =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifact_dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = arg(&args, "--requests", 8);
    let prompt_len: usize = arg(&args, "--prompt-len", 16);
    let gen_len: usize = arg(&args, "--gen-len", 24);

    println!("== Symbiosis quickstart: base model as-a-service ==");
    println!("model={} layers={} d_model={}", SYM_TINY.name,
             SYM_TINY.n_layers, SYM_TINY.d_model);

    let dep = Deployment::start(&SYM_TINY, &artifact_dir,
                                BatchPolicy::opportunistic_default(),
                                Placement::Local)?;

    // four tenants with different PEFT configurations share the base
    let tenants: Vec<(&str, Option<Adapter>)> = vec![
        ("base (no adapter)", None),
        ("lora-r8-qkvo",
         Some(Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir, 8,
                                           LoraTargets::QKVO, 2.0)?)),
        ("lora-r64-qkvo",
         Some(Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir, 64,
                                           LoraTargets::QKVO, 0.25)?)),
        ("ia3", Some(Adapter::ia3(&SYM_TINY))),
    ];

    // warm-up + the one-call path: a whole request through generate().
    // Running it first keeps lazy HLO compiles out of the measured
    // latencies below.
    let mut smoke = dep.session().build()?;
    let warm_prompt: Vec<i32> =
        (0..prompt_len).map(|k| (k * 3 % 256) as i32).collect();
    let out = smoke.generate(&warm_prompt, &GenerationConfig::greedy(8))?;
    println!("generate() smoke: {} tokens for the base tenant",
             out[0].len());
    drop(smoke);

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, (name, adapter)) in tenants.into_iter().enumerate() {
        // one session (= one registered client) per tenant; reset()
        // clears the per-request state between requests
        let mut b = dep.session();
        if let Some(a) = adapter {
            b = b.adapter(a);
        }
        let sess = b.build()?;
        handles.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let mut sess = sess;
            let mut lat = LatencyStats::new();
            let mut tokens_out = 0u64;
            for r in 0..n_requests {
                let prompt: Vec<i32> = (0..prompt_len)
                    .map(|k| ((i * 131 + r * 17 + k * 3) % 256) as i32)
                    .collect();
                sess.prefill(&prompt)?;
                for _ in 1..gen_len {
                    let step = Instant::now();
                    sess.decode_step()?;
                    lat.record(step.elapsed());
                }
                tokens_out += gen_len as u64;
                sess.reset()?;
            }
            Ok((name, lat, tokens_out))
        }));
    }

    let mut total_tokens = 0u64;
    println!("\n{:<20} {:>10} {:>10} {:>10} {:>8}", "tenant",
             "p50 (ms)", "p99 (ms)", "mean (ms)", "tokens");
    for h in handles {
        let (name, lat, tokens) = h.join().unwrap()?;
        total_tokens += tokens;
        println!("{:<20} {:>10.2} {:>10.2} {:>10.2} {:>8}", name,
                 lat.p50() * 1e3, lat.p99() * 1e3, lat.mean() * 1e3,
                 tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\naggregate: {} tokens in {:.2}s = {:.1} tok/s",
             total_tokens, wall, total_tokens as f64 / wall);

    let estats = dep.engine.stats();
    let stats = dep.shutdown();
    println!("executor: {} requests, {} flushes, avg batch {:.2} \
              clients, mean queue wait {:.2}ms, padding overhead {:.1}%",
             stats.requests_served, stats.n_flushes,
             stats.mean_batch_clients(), stats.mean_wait_secs() * 1e3,
             stats.padding_overhead() * 100.0);
    println!("engine: {} executes ({:.0}us avg), {} compiles \
              ({:.2}s total), weight-literal cache {}/{} hits",
             estats.executes,
             estats.execute_secs / estats.executes.max(1) as f64 * 1e6,
             estats.compiles, estats.compile_secs,
             estats.weight_cache_hits,
             estats.weight_cache_hits + estats.weight_cache_misses);
    Ok(())
}

fn arg<T: std::str::FromStr>(args: &[String], name: &str, default: T)
                             -> T {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}
