//! Multi-adapter fine-tuning: N independent trainers share one base.
//!
//! The paper's headline use case (section 4.2): several tenants
//! fine-tune *different* LoRA configurations (Table 2's LoRA1..4)
//! against the same frozen base model, each driving its own iterations
//! while the executor opportunistically batches their layer invocations.
//! Trains on a synthetic next-token corpus with learnable structure and
//! logs each client's loss curve — losses must go down independently.
//!
//! Run:  cargo run --release --example multi_adapter_finetune -- \
//!           --clients 3 --steps 60

use std::path::PathBuf;
use std::time::Instant;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::{lora_table2, LoraTargets};
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             Placement};

/// Synthetic corpus: token[i+1] = (a * token[i] + b) mod vocab — an
/// affine next-token rule each adapter can learn.  Each client cycles
/// through a small fixed set of batches so per-epoch average losses are
/// directly comparable.
const BATCHES_PER_EPOCH: usize = 4;

fn batch_for(client: usize, step: usize, seq: usize)
             -> (Vec<i32>, Vec<i32>) {
    let vocab = SYM_TINY.vocab as i64;
    let a = [3, 5, 7, 11, 13, 17, 19, 23][client % 8] as i64;
    let b = (client * 29 + 1) as i64;
    let batch_id = step % BATCHES_PER_EPOCH;
    let mut x = ((batch_id * 37 + client * 101) % SYM_TINY.vocab) as i64;
    let mut tokens = Vec::with_capacity(seq);
    for _ in 0..seq {
        tokens.push(x as i32);
        x = (a * x + b).rem_euclid(vocab);
    }
    let mut labels: Vec<i32> = tokens[1..].to_vec();
    labels.push(((a * x + b).rem_euclid(vocab)) as i32);
    (tokens, labels)
}

fn main() -> anyhow::Result<()> {
    let artifact_dir =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifact_dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let args: Vec<String> = std::env::args().collect();
    let n_clients: usize = arg(&args, "--clients", 3);
    let steps: usize = arg(&args, "--steps", 60);
    let seq: usize = arg(&args, "--seq", 32);

    println!("== Symbiosis multi-adapter fine-tuning ==");
    println!("{n_clients} trainers x {steps} steps, seq={seq}, \
              shared base = {}", SYM_TINY.name);

    let dep = Deployment::start(&SYM_TINY, &artifact_dir,
                                BatchPolicy::opportunistic_default(),
                                Placement::Local)?;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        // rotate through the paper's Table 2 adapter configs
        let which = 1 + (c % 4);
        let (rank, targets) = lora_table2(which);
        let scale = 16.0 / rank as f32;
        let adapter = Adapter::lora_from_artifacts(
            &SYM_TINY, &artifact_dir, rank, LoraTargets::QKVO, scale)?;
        // restrict to the configured targets by rebuilding if needed
        let adapter = if targets == LoraTargets::QKVO {
            adapter
        } else {
            Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir, rank,
                                         targets, scale)?
        };
        let tr = dep.trainer().adapter(adapter).lr(5e-3).build()?;
        handles.push(std::thread::spawn(move || -> anyhow::Result<_> {
            let mut tr = tr;
            let mut curve = Vec::with_capacity(steps);
            for s in 0..steps {
                let (tokens, labels) = batch_for(c, s, seq);
                let out = tr.train_step(&tokens, &labels)?;
                curve.push(out.loss);
            }
            Ok((c, which, rank, curve))
        }));
    }

    println!("\n{:<8} {:<8} {:<6} {:>12} {:>12} {:>12}", "client",
             "config", "rank", "epoch[0]", "epoch[mid]", "epoch[last]");
    let mut all_ok = true;
    let mut total_tokens = 0usize;
    for h in handles {
        let (c, which, rank, curve) = h.join().unwrap()?;
        total_tokens += curve.len() * seq;
        // epoch-averaged loss (each epoch = the same rotating batches)
        let epoch = |e: usize| -> f32 {
            let lo = e * BATCHES_PER_EPOCH;
            let hi = (lo + BATCHES_PER_EPOCH).min(curve.len());
            curve[lo..hi].iter().sum::<f32>() / (hi - lo) as f32
        };
        let n_epochs = curve.len() / BATCHES_PER_EPOCH;
        let first = epoch(0);
        let mid = epoch(n_epochs / 2);
        let last = epoch(n_epochs - 1);
        let ok = last < first;
        all_ok &= ok;
        println!("{:<8} {:<8} {:<6} {:>12.4} {:>12.4} {:>12.4}  {}",
                 c, format!("LoRA{which}"), rank, first, mid, last,
                 if ok { "↓" } else { "!!" });
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n{} total training tokens in {:.1}s = {:.0} tok/s \
              across {} clients", total_tokens, wall,
             total_tokens as f64 / wall, n_clients);

    let stats = dep.shutdown();
    println!("executor: {} flushes, avg batch {:.2} clients, padding \
              overhead {:.1}%", stats.n_flushes,
             stats.mean_batch_clients(),
             stats.padding_overhead() * 100.0);
    if !all_ok {
        anyhow::bail!("a loss curve failed to decrease");
    }
    println!("all loss curves decreased ✓");
    Ok(())
}

fn arg<T: std::str::FromStr>(args: &[String], name: &str, default: T)
                             -> T {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}
