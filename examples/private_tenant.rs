//! Privacy-preserving multi-tenancy (paper section 3.8, Fig. 21).
//!
//! A tenant whose adapter was trained on confidential data uses a
//! third-party base-model service over the network.  The client adds
//! pre-registered noise to every activation it ships; the executor only
//! ever sees `x + n`, and the tenant subtracts the pre-computed noise
//! effect from the result.  This example verifies the protocol is
//! *exact* (same generated tokens with and without privacy) and measures
//! its overhead on a TCP-class link vs plain local serving.
//!
//! Run:  cargo run --release --example private_tenant

use std::path::PathBuf;
use std::time::Instant;

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::privacy::{NoiseGen, PrivacyCtx};
use symbiosis::coordinator::proto::LayerId;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             GenerationConfig, Placement};
use symbiosis::transport::LinkKind;

fn main() -> anyhow::Result<()> {
    let artifact_dir =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifact_dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    println!("== Symbiosis private tenant over an untrusted base \
              service ==");
    let dep = Deployment::start(&SYM_TINY, &artifact_dir,
                                BatchPolicy::NoLockstep,
                                Placement::Local)?;
    let adapter = Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir,
                                               8, LoraTargets::QKVO,
                                               2.0)?;
    let prompt: Vec<i32> =
        (0..16).map(|i| (i * 11 % 256) as i32).collect();
    let gen_len = 16;

    // -- plain tenant (no privacy), local link --
    let mut plain = dep.session().adapter(adapter.clone()).build()?;
    let t0 = Instant::now();
    plain.generate(&prompt, &GenerationConfig::greedy(gen_len))?;
    let plain_time = t0.elapsed().as_secs_f64();
    let want = plain.generated[0].clone();
    let plain_link = plain.core.virt.link_time();
    drop(plain);

    // -- private tenant: noise on every linear layer, TCP-class link --
    let privacy = PrivacyCtx::new();
    let mut gen = NoiseGen::new(0xDEADBEEF, 0.1);
    let tx = dep.executor.sender();
    let (d, f) = (SYM_TINY.d_model, SYM_TINY.d_ff);
    let setup0 = Instant::now();
    for l in 0..SYM_TINY.n_layers {
        for (layer, din) in [
            (LayerId::Qkv(l), d),
            (LayerId::AttnOut(l), d),
            (LayerId::MlpUp(l), d),
            (LayerId::MlpDown(l), f),
        ] {
            // pool of 4 rotating noise values per layer (section 3.8:
            // "prepare several noise values in advance")
            privacy.register_layer(&tx, layer, prompt.len(), din,
                                   &mut gen, 4)?;
        }
    }
    privacy.register_layer(&tx, LayerId::LmHead, prompt.len(), d,
                           &mut gen, 4)?;
    let setup_time = setup0.elapsed().as_secs_f64();
    let mut private = dep.session()
        .adapter(adapter)
        .link(LinkKind::Tcp)
        .privacy(privacy)
        .build()?;
    let t1 = Instant::now();
    private.generate(&prompt, &GenerationConfig::greedy(gen_len))?;
    let private_time = t1.elapsed().as_secs_f64();

    assert_eq!(private.generated[0], want,
               "privacy protocol must not change outputs");
    println!("outputs identical with and without privacy ✓ \
              (noise added, n_eff subtracted — exact by linearity)");
    println!("\n{:<28} {:>12} {:>16}", "tenant", "wall (ms)",
             "sim link time");
    println!("{:<28} {:>12.1} {:>13.2} ms", "plain / local",
             plain_time * 1e3, plain_link * 1e3);
    println!("{:<28} {:>12.1} {:>13.2} ms", "private / tcp",
             private_time * 1e3,
             private.core.virt.link_time() * 1e3);
    println!("noise setup (once per tenant): {:.1} ms for {} layers x 4 \
              noise values", setup_time * 1e3,
             SYM_TINY.n_layers * 4 + 1);
    println!("\nper-iteration privacy arithmetic = one add + one \
              subtract per layer; the network, not the noise, dominates \
              (paper Fig. 21).");
    let n_private = {
        let p = private.core.virt.privacy.as_ref().unwrap();
        let log = p.sent_log.lock().unwrap();
        log.len()
    };
    println!("executor observed {n_private} noised activations, 0 raw");
    drop(private);
    dep.shutdown();
    Ok(())
}
