"""L2: the transformer decomposed along Symbiosis's split-execution line.

Two things live here:

1. **Artifact functions** — the individual jax functions (calling the L1
   Pallas kernels) that ``aot.py`` lowers to HLO text, one per
   (operation, shape-bucket).  These are exactly the units the Rust
   coordinator composes at run time: *base* artifacts execute in the base
   executor, *client* artifacts in each client.

2. **Monolithic reference** — the same model as one pure-jnp function
   (``forward`` / ``train_step``), used to produce golden vectors that the
   Rust split-execution integration tests must match (within fp32
   tolerance).  This encodes the paper's core correctness claim: "the
   output with Symbiosis is exactly identical to that of the baseline".

Model shape (executable family): decoder-only, learned absolute position
embeddings (GPT2-style; RoPE is avoided so the decode path stays
position-explicit), pre-RMSNorm, fused-QKV projections, GELU MLP.
Client-side cheap elementwise ops (rmsnorm, gelu, residual) are implemented
natively in Rust; their formulas here are the normative reference.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import configs
from .kernels import attention as katt
from .kernels import linear as klin
from .kernels import lora as klora
from .kernels import ref


# ---------------------------------------------------------------------------
# Deterministic parameter generation (shared with weights.bin)
# ---------------------------------------------------------------------------

def init_params(cfg: configs.ModelConfig, seed: int = 0):
    """Deterministic base-model weights, scaled for stable forward passes."""
    rng = np.random.default_rng(seed)
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq

    def w(*shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jnp.asarray(
            rng.standard_normal(shape, dtype=np.float32) * scale)

    params = {
        "embed": w(v, d, scale=0.02),
        "pos": w(s, d, scale=0.02),
        "norm_f": jnp.ones((d,), jnp.float32),
        "lm_head_w": w(d, v),
        "lm_head_b": jnp.zeros((v,), jnp.float32),
    }
    for l in range(cfg.n_layers):
        params.update({
            f"l{l}.norm1": jnp.ones((d,), jnp.float32),
            f"l{l}.wqkv": w(d, 3 * d),
            f"l{l}.bqkv": jnp.zeros((3 * d,), jnp.float32),
            f"l{l}.wo": w(d, d),
            f"l{l}.bo": jnp.zeros((d,), jnp.float32),
            f"l{l}.norm2": jnp.ones((d,), jnp.float32),
            f"l{l}.wup": w(d, f),
            f"l{l}.bup": jnp.zeros((f,), jnp.float32),
            f"l{l}.wdown": w(f, d),
            f"l{l}.bdown": jnp.zeros((d,), jnp.float32),
        })
    return params


def init_lora(cfg: configs.ModelConfig, rank: int,
              targets=("q", "k", "v", "o"), seed: int = 1):
    """Deterministic LoRA adapter init.  B is standardly zero-initialized,
    but that makes first-iteration dA vanish — for meaningful golden
    gradients we use a small nonzero B."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    adapter = {}
    for l in range(cfg.n_layers):
        for t in targets:
            adapter[f"l{l}.{t}.a"] = jnp.asarray(
                rng.standard_normal((d, rank), dtype=np.float32) / d)
            adapter[f"l{l}.{t}.b"] = jnp.asarray(
                rng.standard_normal((rank, d), dtype=np.float32) * 0.01)
    return adapter


# ---------------------------------------------------------------------------
# Artifact functions (lowered one-by-one by aot.py)
# ---------------------------------------------------------------------------
# Base-executor artifacts — Pallas linear kernels over flattened tokens.

def art_linear_fwd(x, w, b):
    return (klin.linear_flat(x, w, b),)


def art_linear_bwd(dy, w):
    return (klin.linear_bwd_data(dy, w),)


# Client artifacts — attention (Pallas) and LoRA (Pallas).

def art_attn_prefill(q, k, v, *, scale):
    return (katt.attention_prefill(q, k, v, scale),)


def art_attn_decode(q, k, v, kv_len, *, scale):
    return (katt.attention_decode(q, k, v, kv_len, scale),)


def art_attn_bwd(q, k, v, dout, *, scale):
    return tuple(ref.attention_bwd(q, k, v, dout, scale))


def art_lora_fwd(x, a, b):
    # LoRA scale (alpha/r) is applied natively in Rust — cheap elementwise.
    return (klora.lora_apply(x, a, b, 1.0),)


def art_lora_bwd(x, dy, a, b):
    return tuple(klora.lora_bwd(x, dy, a, b, 1.0))


def art_embed(tokens, positions, emb, pos):
    return (emb[tokens] + pos[positions],)


def art_xent(logits, labels, weights):
    return tuple(ref.softmax_xent(logits, labels, weights))


def art_adam(p, g, m, v, t):
    return tuple(ref.adam_step(p, g, m, v, t))


# ---------------------------------------------------------------------------
# Monolithic reference model (pure jnp, differentiable)
# ---------------------------------------------------------------------------

def _split_heads(x, n_heads):
    t, d = x.shape
    h = d // n_heads
    # (T, D) -> (NH, T, H); the request batch is folded in the caller's loop
    return x.reshape(t, n_heads, h).transpose(1, 0, 2)


def _merge_heads(x):
    nh, t, h = x.shape
    return x.transpose(1, 0, 2).reshape(t, nh * h)


def forward(cfg: configs.ModelConfig, params, tokens, adapter=None,
            lora_scale: float = 2.0, targets=("q", "k", "v", "o")):
    """Reference forward for ONE sequence. tokens: (S,) int32 -> (S, V).

    ``adapter`` is a LoRA dict from init_lora (or None for the plain base
    model). The math mirrors what Rust composes from artifacts exactly.
    """
    nh = cfg.n_heads
    scale = 1.0 / np.sqrt(cfg.d_head)
    s = tokens.shape[0]
    h = params["embed"][tokens] + params["pos"][jnp.arange(s)]
    for l in range(cfg.n_layers):
        a_in = ref.rmsnorm(h, params[f"l{l}.norm1"])
        qkv = ref.linear_flat(a_in, params[f"l{l}.wqkv"],
                              params[f"l{l}.bqkv"])
        q, k, v = jnp.split(qkv, 3, axis=-1)
        if adapter is not None:
            if "q" in targets:
                q = q + ref.lora_apply(a_in, adapter[f"l{l}.q.a"],
                                       adapter[f"l{l}.q.b"], lora_scale)
            if "k" in targets:
                k = k + ref.lora_apply(a_in, adapter[f"l{l}.k.a"],
                                       adapter[f"l{l}.k.b"], lora_scale)
            if "v" in targets:
                v = v + ref.lora_apply(a_in, adapter[f"l{l}.v.a"],
                                       adapter[f"l{l}.v.b"], lora_scale)
        qh, kh, vh = (_split_heads(x, nh) for x in (q, k, v))
        attn = _merge_heads(ref.attention_prefill(qh, kh, vh, scale))
        o = ref.linear_flat(attn, params[f"l{l}.wo"], params[f"l{l}.bo"])
        if adapter is not None and "o" in targets:
            o = o + ref.lora_apply(attn, adapter[f"l{l}.o.a"],
                                   adapter[f"l{l}.o.b"], lora_scale)
        h = h + o
        m_in = ref.rmsnorm(h, params[f"l{l}.norm2"])
        u = ref.gelu(ref.linear_flat(m_in, params[f"l{l}.wup"],
                                     params[f"l{l}.bup"]))
        h = h + ref.linear_flat(u, params[f"l{l}.wdown"],
                                params[f"l{l}.bdown"])
    hf = ref.rmsnorm(h, params["norm_f"])
    return ref.linear_flat(hf, params["lm_head_w"], params["lm_head_b"])


def loss_fn(cfg, params, adapter, tokens, labels, lora_scale=2.0,
            targets=("q", "k", "v", "o")):
    logits = forward(cfg, params, tokens, adapter, lora_scale, targets)
    loss, _ = ref.softmax_xent(logits, labels)
    return loss


def train_step(cfg, params, adapter, tokens, labels, lora_scale=2.0,
               targets=("q", "k", "v", "o")):
    """Reference loss + LoRA grads for one sequence — golden for the Rust
    hand-rolled split-execution backward."""
    loss, grads = jax.value_and_grad(
        lambda ad: loss_fn(cfg, params, ad, tokens, labels, lora_scale,
                           targets))(adapter)
    return loss, grads


def generate(cfg, params, prompt, n_new, adapter=None, lora_scale=2.0):
    """Greedy decoding reference (recomputes the full prefix each step —
    a correctness oracle only, not a performance path)."""
    toks = list(np.asarray(prompt))
    for _ in range(n_new):
        logits = forward(cfg, params, jnp.asarray(toks, jnp.int32), adapter,
                         lora_scale)
        toks.append(int(jnp.argmax(logits[-1])))
    return np.asarray(toks[len(prompt):], dtype=np.int32)
