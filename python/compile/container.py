"""SYMT — the little-endian named-tensor container shared with Rust.

Layout:
    magic   b"SYMT"
    version u32 = 1
    count   u32
    per tensor:
        name_len u32, name utf-8 bytes
        dtype    u8   (0 = f32, 1 = i32)
        ndim     u8
        dims     u32 * ndim
        data     raw little-endian bytes (row-major)

The Rust reader lives in ``rust/src/tensor/container.rs``; keep the two in
lockstep (there is a round-trip test on each side).
"""

import struct

import numpy as np

MAGIC = b"SYMT"
VERSION = 1
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
_DTYPES_INV = {0: np.float32, 1: np.int32}


def write_tensors(path, tensors: dict):
    """Write {name: np.ndarray} to the SYMT container at ``path``."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            if arr.dtype == np.int64:
                arr = arr.astype(np.int32)
            code = _DTYPES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def read_tensors(path) -> dict:
    """Read a SYMT container back into {name: np.ndarray}."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        version, count = struct.unpack("<II", f.read(8))
        assert version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(_DTYPES_INV[code])
            n = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(
                f.read(n * dt.itemsize), dtype=dt).reshape(dims)
    return out
