"""L1 Pallas kernels: client-side attention (prefill + decode).

Attention is the client-side hot spot in Symbiosis — it stays with the
client together with the KV cache (paper section 3.2), so these kernels are
lowered into the *client* artifacts, not the base-executor ones.

Prefill is a FlashAttention-style tiled kernel: the grid walks
(batch*heads, q-blocks); inside the kernel a fori_loop streams KV blocks
through VMEM keeping a running max / normalizer, so the S x S score matrix
is never materialized in HBM.  Decode is a single-query row against the
streamed KV cache — exactly the access pattern the CPU-offloaded cache path
uses (paper section 3.4: "the executing layer's KV cache is fetched right
before their execution").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, scale, seq):
    """One q-block of causal flash attention for one (batch, head)."""
    qi = pl.program_id(1)
    q = q_ref[0]  # (bq, H)
    q_base = qi * bq

    n_kv = seq // bk

    def body(j, carry):
        acc, m, l = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32) * scale
        # causal mask: query position q_base+i attends kv position <= it
        qpos = q_base + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    h = q.shape[-1]
    acc0 = jnp.zeros((bq, h), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = acc / l[:, None]


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("scale", "bq", "bk"))
def attention_prefill(q, k, v, scale, bq=128, bk=128):
    """Causal self-attention. q, k, v: (BH, S, H) -> (BH, S, H)."""
    bh, s, h = q.shape
    bq = _pick_block(s, bq)
    bk = _pick_block(s, bk)
    grid = (bh, s // bq)
    return pl.pallas_call(
        functools.partial(_prefill_kernel, bq=bq, bk=bk, scale=scale, seq=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, h), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, h), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, h), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, h), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, h), jnp.float32),
        interpret=True,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, bk, scale, seq):
    """Single query row vs the full KV cache for one (batch, head).

    ``len_ref`` holds the true cache length; positions >= it are bucket
    padding and are masked out (the cache is padded up to the artifact's
    shape bucket by the client).
    """
    q = q_ref[0, 0]  # (H,)
    kv_len = len_ref[0]

    def body(j, carry):
        acc, m, l = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        v_blk = pl.load(v_ref, (0, pl.dslice(j * bk, bk), slice(None)))
        s = (k_blk @ q) * scale  # (bk,)
        pos = j * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc = acc * alpha + p @ v_blk
        return acc, m_new, l_new

    h = q.shape[-1]
    acc, _, l = jax.lax.fori_loop(
        0, seq // bk, body,
        (jnp.zeros((h,), jnp.float32), jnp.float32(NEG_INF),
         jnp.float32(0.0)))
    o_ref[0, 0] = acc / l


@functools.partial(jax.jit, static_argnames=("scale", "bk"))
def attention_decode(q, k, v, kv_len, scale, bk=128):
    """One-token decode. q: (BH, 1, H), k, v: (BH, S, H), kv_len: (1,) i32
    true cache length -> (BH, 1, H)."""
    bh, s, h = k.shape
    bk = _pick_block(s, bk)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk, scale=scale, seq=s),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, 1, h), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s, h), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s, h), lambda b: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, h), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, h), jnp.float32),
        interpret=True,
    )(q, k, v, kv_len)
