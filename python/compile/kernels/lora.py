"""L1 Pallas kernel: fused LoRA adapter application.

y = scale * (x @ A) @ B with rank r << d.  The fusion keeps the rank-r
intermediate (bt x r) in VMEM between the two matmuls — on TPU this avoids
an HBM round-trip that would otherwise dominate, since the adapter path is
bandwidth-bound by design (arithmetic intensity ~ r).  A and B are small
enough (d*r) to stay fully resident across the token grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lora_kernel(x_ref, a_ref, b_ref, o_ref, *, scale):
    xa = jnp.dot(x_ref[...], a_ref[...],
                 preferred_element_type=jnp.float32)  # (bt, r) in VMEM
    o_ref[...] = scale * jnp.dot(xa, b_ref[...],
                                 preferred_element_type=jnp.float32)


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("scale", "bt"))
def lora_apply(x, a, b, scale, bt=128):
    """x: (T, Din), a: (Din, r), b: (r, Dout) -> scale * x a b: (T, Dout)."""
    t, din = x.shape
    r, dout = b.shape
    bt = _pick_block(t, bt)
    return pl.pallas_call(
        functools.partial(_lora_kernel, scale=scale),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, din), lambda i: (i, 0)),
            pl.BlockSpec((din, r), lambda i: (0, 0)),
            pl.BlockSpec((r, dout), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, a, b)


def _lora_bwd_kernel(x_ref, dy_ref, a_ref, b_ref, da_ref, db_ref, dx_ref,
                     *, scale, n_t_blocks):
    """Accumulates dA / dB across token blocks; emits dx per block.

    The rank-r intermediates (dy B^T and x A) live in VMEM; dA/dB tiles use
    output-revisiting accumulation across the token grid.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...]
    dy = dy_ref[...]
    dyb = jnp.dot(dy, b_ref[...].T, preferred_element_type=jnp.float32)
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    da_ref[...] += scale * jnp.dot(x.T, dyb,
                                   preferred_element_type=jnp.float32)
    db_ref[...] += scale * jnp.dot(xa.T, dy,
                                   preferred_element_type=jnp.float32)
    dx_ref[...] = scale * jnp.dot(dyb, a_ref[...].T,
                                  preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("scale", "bt"))
def lora_bwd(x, dy, a, b, scale, bt=128):
    """Gradients of the LoRA path: returns (dA, dB, dx)."""
    t, din = x.shape
    r, dout = b.shape
    bt = _pick_block(t, bt)
    n_t = t // bt
    return pl.pallas_call(
        functools.partial(_lora_bwd_kernel, scale=scale, n_t_blocks=n_t),
        grid=(n_t,),
        in_specs=[
            pl.BlockSpec((bt, din), lambda i: (i, 0)),
            pl.BlockSpec((bt, dout), lambda i: (i, 0)),
            pl.BlockSpec((din, r), lambda i: (0, 0)),
            pl.BlockSpec((r, dout), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((din, r), lambda i: (0, 0)),
            pl.BlockSpec((r, dout), lambda i: (0, 0)),
            pl.BlockSpec((bt, din), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((din, r), jnp.float32),
            jax.ShapeDtypeStruct((r, dout), jnp.float32),
            jax.ShapeDtypeStruct((t, din), jnp.float32),
        ],
        interpret=True,
    )(x, dy, a, b)
