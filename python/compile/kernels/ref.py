"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has an entry here with the identical
signature; pytest (and hypothesis sweeps) assert allclose between the two.
These are also the bodies used by ``model.py`` for the monolithic reference
model the Rust integration tests compare against.
"""

import jax
import jax.numpy as jnp


def linear_flat(x, w, b):
    """y = x @ w + b over a flattened token axis.

    x: (T, Din), w: (Din, Dout), b: (Dout,) -> (T, Dout)
    """
    return x @ w + b


def linear_bwd_data(dy, w):
    """Memory-optimized backward of a frozen linear layer: dX = dY . W^T.

    The paper's section 3.6 insight — no saved forward activations needed.
    dy: (T, Dout), w: (Din, Dout) -> (T, Din)
    """
    return dy @ w.T


def attention_prefill(q, k, v, scale):
    """Causal self-attention over full sequences.

    q, k, v: (BH, S, H) with BH = batch * n_heads. Returns (BH, S, H).
    """
    s = q.shape[1]
    scores = jnp.einsum("bqh,bkh->bqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v)


def attention_decode(q, k, v, kv_len, scale):
    """Single-query attention against a KV cache.

    q: (BH, 1, H), k, v: (BH, S, H) -> (BH, 1, H).  ``kv_len`` (i32 scalar,
    shape (1,)) masks cache positions >= kv_len: the HLO artifact is
    shape-specialized to a bucket S, so the client pads the cache to S and
    passes the true length.
    """
    s = k.shape[1]
    scores = jnp.einsum("bqh,bkh->bqk", q, k) * scale
    valid = jnp.arange(s)[None, None, :] < kv_len[0]
    scores = jnp.where(valid, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v)


def attention_bwd(q, k, v, dout, scale):
    """Gradients of causal prefill attention w.r.t. q, k, v.

    Recomputes the probabilities from (q, k) — the client keeps q/k/v in its
    runtime state, so nothing extra is stored (paper section 3.6 applied to
    the client side).
    """
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_prefill(q_, k_, v_, scale),
                     q, k, v)
    return vjp(dout)


def lora_apply(x, a, b, scale):
    """LoRA adapter path: y = scale * (x @ A) @ B.

    x: (T, Din), a: (Din, r), b: (r, Dout) -> (T, Dout)
    """
    return scale * ((x @ a) @ b)


def lora_bwd(x, dy, a, b, scale):
    """Gradients of the LoRA path: (dA, dB, dx).

    dA = scale * x^T (dy B^T);  dB = scale * (xA)^T dy;  dx = scale * dy B^T A^T
    """
    xa = x @ a
    dyb = dy @ b.T
    da = scale * (x.T @ dyb)
    db = scale * (xa.T @ dy)
    dx = scale * (dyb @ a.T)
    return da, db, dx


def ia3_apply(x, scale_vec):
    """IA3: elementwise rescale of activations. x: (T, D), scale_vec: (D,)."""
    return x * scale_vec[None, :]


def softmax_xent(logits, labels, weights=None):
    """Weighted-mean cross-entropy and its gradient w.r.t. logits.

    logits: (T, V) f32, labels: (T,) int32, weights: (T,) f32 (1 for real
    tokens, 0 for bucket padding) -> (loss (), dlogits (T, V)).
    """
    if weights is None:
        weights = jnp.ones(logits.shape[0], jnp.float32)
    denom = weights.sum()
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = (nll * weights).sum() / denom
    dlogits = (jax.nn.softmax(logits, axis=-1)
               - jax.nn.one_hot(labels, logits.shape[-1]))
    dlogits = dlogits * (weights / denom)[:, None]
    return loss, dlogits


def adam_step(p, g, m, v, t, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam update over a flat parameter vector. t is the 1-based step."""
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    return p - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


def rmsnorm(x, gain, eps=1e-6):
    """RMSNorm: x * gain / rms(x). x: (T, D), gain: (D,)."""
    rms = jnp.sqrt((x * x).mean(axis=-1, keepdims=True) + eps)
    return x / rms * gain[None, :]


def rmsnorm_bwd(x, gain, dy, eps=1e-6):
    """dx of RMSNorm (gain is frozen base-model state in Symbiosis)."""
    _, vjp = jax.vjp(lambda x_: rmsnorm(x_, gain, eps), x)
    return vjp(dy)[0]


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def gelu_bwd(x, dy):
    _, vjp = jax.vjp(gelu, x)
    return vjp(dy)[0]
