"""L1 Pallas kernel: flattened token-batched linear layer.

This is the base executor's hot spot. Symbiosis flattens the
``batch x seq_len`` inputs of *all* clients batched at a layer into a single
token axis (valid because nn.Linear / Conv1D are position-independent,
paper section 3.7), so the kernel is a single ``(T, Din) @ (Din, Dout) + b``
with no padding between requests.

TPU mapping (DESIGN.md section 4): the grid tiles tokens x dout into
MXU-shaped blocks; each grid step loads an x-block and a w-block into VMEM
(Pallas pipelines the HBM->VMEM copies across grid steps, giving the
double-buffering the paper got from CUDA threadblocks). ``interpret=True``
everywhere — the CPU PJRT plugin cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k_blocks):
    """One (bt x bd) output tile; loops over the Din dimension in blocks.

    The k-loop accumulates into the output tile, which stays resident in
    VMEM across the k grid dimension (output revisiting).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(k == n_k_blocks - 1)
    def _bias():
        o_ref[...] += b_ref[...][None, :]


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (block shapes must tile)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bt", "bd", "bk"))
def linear_flat(x, w, b, bt=128, bd=128, bk=512):
    """y = x @ w + b with x: (T, Din), w: (Din, Dout), b: (Dout,).

    Block sizes default to MXU-friendly tiles; for the tiny executable
    configs they clamp to divisors of the actual dims.
    """
    t, din = x.shape
    dout = w.shape[1]
    bt = _pick_block(t, bt)
    bd = _pick_block(dout, bd)
    bk = _pick_block(din, bk)
    n_k = din // bk
    grid = (t // bt, dout // bd, n_k)
    return pl.pallas_call(
        functools.partial(_linear_kernel, n_k_blocks=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),
            pl.BlockSpec((bd,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, w, b)


def _bwd_data_kernel(dy_ref, w_ref, o_ref, *, n_k_blocks):
    """dX tile = sum_k dY[:, k-block] @ W[:, k-block]^T."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(dy_ref[...], w_ref[...].T,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bt", "bd", "bk"))
def linear_bwd_data(dy, w, bt=128, bd=128, bk=512):
    """dX = dY @ W^T — the memory-optimized backward of a frozen linear
    layer (paper section 3.6): recomputed from parameters, nothing saved.

    dy: (T, Dout), w: (Din, Dout) -> (T, Din)
    """
    t, dout = dy.shape
    din = w.shape[0]
    bt = _pick_block(t, bt)
    bd = _pick_block(din, bd)
    bk = _pick_block(dout, bk)
    grid = (t // bt, din // bd, dout // bk)
    return pl.pallas_call(
        functools.partial(_bwd_data_kernel, n_k_blocks=dout // bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bd, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bt, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, din), jnp.float32),
        interpret=True,
    )(dy, w)
