"""AOT compile path: lower every artifact to HLO text + export weights.

Run once via ``make artifacts``.  Produces, under ``artifacts/``:

* ``<name>.hlo.txt``      — one HLO-text module per (op, shape-bucket).
  HLO *text* is the interchange format — jax >= 0.5 emits HloModuleProto
  with 64-bit instruction ids that the xla crate's xla_extension 0.5.1
  rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
* ``manifest.txt``        — model dims, bucket tables, and per-artifact
  input/output specs, parsed by ``rust/src/runtime/manifest.rs``.
* ``weights_<model>.bin`` — deterministic base-model weights (SYMT).
* ``adapters_<model>.bin``— deterministic LoRA adapter inits per rank.
* ``golden_<model>.bin``  — reference vectors (forward logits, training
  loss/grads/updated-adapter, greedy generation) that the Rust
  split-execution integration tests must reproduce.

Python never runs on the request path: after this script, the Rust binary
is self-contained.
"""

import argparse
import functools
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, container, model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(*dims, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(dims, dtype)


def _fmt_spec(name, s):
    dims = "x".join(str(d) for d in s.shape) or "1"
    dt = {jnp.float32: "f32", jnp.int32: "i32"}[s.dtype.type]
    return f"{name}:{dt}:{dims}"


class ArtifactSet:
    """Collects (name, fn, arg-specs, out-names) and lowers them all."""

    def __init__(self):
        self.items = {}

    def add(self, name, fn, arg_specs, in_names, out_names):
        if name not in self.items:
            self.items[name] = (fn, arg_specs, in_names, out_names)

    def lower_all(self, out_dir, skip_existing=True):
        lines = []
        t0 = time.time()
        for i, (name, (fn, specs, in_names, out_names)) in enumerate(
                sorted(self.items.items())):
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            lowered = jax.jit(fn).lower(*specs)
            out_specs = [
                jax.ShapeDtypeStruct(a.shape, a.dtype)
                for a in lowered.out_info
            ]
            if not (skip_existing and os.path.exists(path)):
                with open(path, "w") as f:
                    f.write(to_hlo_text(lowered))
            ins = ";".join(_fmt_spec(n, s) for n, s in zip(in_names, specs))
            outs = ";".join(
                _fmt_spec(n, s) for n, s in zip(out_names, out_specs))
            lines.append(f"artifact {name} {name}.hlo.txt in={ins} out={outs}")
            if (i + 1) % 25 == 0:
                print(f"  [{i+1}/{len(self.items)}] "
                      f"{time.time()-t0:.1f}s", file=sys.stderr)
        return lines


def build_artifacts(cfg: configs.ModelConfig) -> ArtifactSet:
    """Enumerate the full artifact inventory for one executable config."""
    arts = ArtifactSet()
    d, f, v, nh, hd = (cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_heads,
                       cfg.d_head)
    scale = 1.0 / np.sqrt(hd)

    # Base-executor linears over flattened tokens.  Dims deduped: for
    # sym-tiny, (d, f) == (d, v), so one artifact serves both layers.
    linear_dims = {(d, 3 * d), (d, d), (d, f), (f, d), (d, v)}
    for t in configs.TOKEN_BUCKETS:
        for (din, dout) in sorted(linear_dims):
            arts.add(
                f"linear_fwd_t{t}_{din}x{dout}", model.art_linear_fwd,
                (_spec(t, din), _spec(din, dout), _spec(dout)),
                ("x", "w", "b"), ("y",))
            arts.add(
                f"linear_bwd_t{t}_{din}x{dout}", model.art_linear_bwd,
                (_spec(t, dout), _spec(din, dout)),
                ("dy", "w"), ("dx",))

    # Client attention.  BH = request_batch * n_heads.
    for b in configs.ATTN_BATCHES:
        bh = b * nh
        for s in configs.SEQ_BUCKETS:
            if s > cfg.max_seq:
                continue
            qkv = (_spec(bh, s, hd),) * 3
            arts.add(
                f"attn_prefill_bh{bh}_s{s}_h{hd}",
                functools.partial(model.art_attn_prefill, scale=scale),
                qkv, ("q", "k", "v"), ("o",))
            arts.add(
                f"attn_decode_bh{bh}_s{s}_h{hd}",
                functools.partial(model.art_attn_decode, scale=scale),
                (_spec(bh, 1, hd), _spec(bh, s, hd), _spec(bh, s, hd),
                 _spec(1, dtype=jnp.int32)),
                ("q", "k", "v", "kv_len"), ("o",))
            arts.add(
                f"attn_bwd_bh{bh}_s{s}_h{hd}",
                functools.partial(model.art_attn_bwd, scale=scale),
                qkv + (_spec(bh, s, hd),),
                ("q", "k", "v", "do"), ("dq", "dk", "dv"))

    # Client LoRA (targets q/k/v/o are all d->d in this model family).
    for t in configs.TOKEN_BUCKETS:
        for r in configs.LORA_RANKS:
            arts.add(
                f"lora_fwd_t{t}_{d}x{r}x{d}", model.art_lora_fwd,
                (_spec(t, d), _spec(d, r), _spec(r, d)),
                ("x", "a", "b"), ("y",))
            arts.add(
                f"lora_bwd_t{t}_{d}x{r}x{d}", model.art_lora_bwd,
                (_spec(t, d), _spec(t, d), _spec(d, r), _spec(r, d)),
                ("x", "dy", "a", "b"), ("da", "db", "dx"))

    # Client embedding + loss.
    for t in configs.TOKEN_BUCKETS:
        arts.add(
            f"embed_t{t}_v{v}_d{d}", model.art_embed,
            (_spec(t, dtype=jnp.int32), _spec(t, dtype=jnp.int32),
             _spec(v, d), _spec(cfg.max_seq, d)),
            ("tokens", "positions", "emb", "pos"), ("h",))
        arts.add(
            f"xent_t{t}_v{v}", model.art_xent,
            (_spec(t, v), _spec(t, dtype=jnp.int32), _spec(t)),
            ("logits", "labels", "weights"), ("loss", "dlogits"))

    # Optimizer step over flat adapter parameter vectors (padded to the
    # nearest bucket; zero-padded grads leave padded params untouched).
    for n in (1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072,
              262144, 524288):
        arts.add(
            f"adam_n{n}",
            lambda p, g, m, vv, t: model.art_adam(p, g, m, vv, t[0]),
            (_spec(n), _spec(n), _spec(n), _spec(n), _spec(1)),
            ("p", "g", "m", "v", "t"), ("p2", "m2", "v2"))
    return arts


def export_weights(cfg, out_dir):
    params = model.init_params(cfg)
    container.write_tensors(
        os.path.join(out_dir, f"weights_{cfg.name}.bin"),
        {k: np.asarray(x) for k, x in params.items()})
    adapters = {}
    for r in configs.LORA_RANKS:
        for k, x in model.init_lora(cfg, r).items():
            adapters[f"r{r}.{k}"] = np.asarray(x)
    container.write_tensors(
        os.path.join(out_dir, f"adapters_{cfg.name}.bin"), adapters)
    return params


def export_golden(cfg, params, out_dir):
    """Golden vectors the Rust integration tests must reproduce."""
    rng = np.random.default_rng(7)
    golden = {}

    tokens16 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    labels16 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    golden["tokens16"] = tokens16
    golden["labels16"] = labels16
    golden["base_logits16"] = np.asarray(
        model.forward(cfg, params, jnp.asarray(tokens16)))

    adapter = model.init_lora(cfg, 8)
    golden["lora8_logits16"] = np.asarray(
        model.forward(cfg, params, jnp.asarray(tokens16), adapter))

    # Bucket-padding exercise: 24 real tokens pad to the 32 bucket.
    tokens24 = rng.integers(0, cfg.vocab, 24).astype(np.int32)
    golden["tokens24"] = tokens24
    golden["base_logits24"] = np.asarray(
        model.forward(cfg, params, jnp.asarray(tokens24)))

    # One training iteration (loss + LoRA grads + Adam-updated adapter).
    loss, grads = model.train_step(cfg, params, adapter,
                                   jnp.asarray(tokens16),
                                   jnp.asarray(labels16))
    golden["train_loss"] = np.asarray(loss).reshape(1)
    for k, g in grads.items():
        golden[f"grad.{k}"] = np.asarray(g)
    for k in adapter:
        p = np.asarray(adapter[k]).ravel()
        g = np.asarray(grads[k]).ravel()
        p2, _, _ = ref.adam_step(jnp.asarray(p), jnp.asarray(g),
                                 jnp.zeros_like(jnp.asarray(p)),
                                 jnp.zeros_like(jnp.asarray(p)), 1.0)
        golden[f"step1.{k}"] = np.asarray(p2).reshape(adapter[k].shape)

    # Greedy generation.
    prompt = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    golden["gen_prompt"] = prompt
    golden["gen_tokens"] = model.generate(cfg, params, prompt, 8, adapter)
    container.write_tensors(
        os.path.join(out_dir, f"golden_{cfg.name}.bin"), golden)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="sym-tiny,sym-small",
                    help="comma-separated executable model names")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the .hlo.txt already exists")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = ["symbiosis-manifest v1"]
    for name in args.models.split(","):
        cfg = configs.EXECUTABLE_MODELS[name]
        print(f"== {name}: lowering artifacts", file=sys.stderr)
        arts = build_artifacts(cfg)
        manifest.append(
            f"model name={cfg.name} d_model={cfg.d_model} "
            f"n_heads={cfg.n_heads} n_layers={cfg.n_layers} "
            f"d_ff={cfg.d_ff} vocab={cfg.vocab} max_seq={cfg.max_seq}")
        manifest.append(
            "buckets tokens=%s seq=%s batches=%s ranks=%s" % (
                ",".join(map(str, configs.TOKEN_BUCKETS)),
                ",".join(map(str, configs.SEQ_BUCKETS)),
                ",".join(map(str, configs.ATTN_BATCHES)),
                ",".join(map(str, configs.LORA_RANKS))))
        manifest += arts.lower_all(args.out_dir,
                                   skip_existing=not args.force)
        print(f"== {name}: weights + golden", file=sys.stderr)
        params = export_weights(cfg, args.out_dir)
        export_golden(cfg, params, args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} manifest lines to "
          f"{args.out_dir}/manifest.txt", file=sys.stderr)


if __name__ == "__main__":
    main()
