"""Model configuration registry shared between the compile path and Rust.

Two families live here:

* ``sym-tiny`` / ``sym-small`` — real, executable transformer configs whose
  weights are generated deterministically at artifact-build time.  These are
  what the Rust coordinator actually runs end-to-end through PJRT.
* The paper's evaluation models (Llama2-7B/13B, GPT2-XL, Granite-20B,
  Starcoder-15B, Gemma2-27B, Llama3-1B) — *analytic* configs: published
  dimensions used by the Rust device simulator for memory/compute accounting
  in the figure reproductions.  They are never lowered to HLO.

The Rust side re-declares the same registry in ``rust/src/config``; the
``aot`` manifest carries the executable config so the two cannot drift.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Dimensions of a decoder-only transformer (GPT2-style absolute
    position embeddings, pre-RMSNorm, GELU MLP)."""

    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    max_seq: int
    dtype: str = "f32"  # executable family is f32 (CPU PJRT)
    executable: bool = True
    # Analytic-only metadata (bytes per parameter on the paper's testbed).
    param_bytes: int = 2

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total base-model parameter count (ties lm_head to embedding: no)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = (
            d * 3 * d + 3 * d      # fused qkv (+bias)
            + d * d + d            # attn out
            + d * f + f            # mlp up
            + f * d + d            # mlp down
            + 2 * d                # two rmsnorm gains
        )
        return v * d + self.max_seq * d + l * per_layer + d + d * v + v


# ---------------------------------------------------------------------------
# Executable family (lowered to HLO, run by the Rust coordinator).
# ---------------------------------------------------------------------------

SYM_TINY = ModelConfig(
    name="sym-tiny", vocab=256, d_model=64, n_heads=4, n_layers=4,
    d_ff=256, max_seq=512,
)

SYM_SMALL = ModelConfig(
    name="sym-small", vocab=512, d_model=128, n_heads=8, n_layers=8,
    d_ff=512, max_seq=512,
)

# ---------------------------------------------------------------------------
# Paper models (analytic; dims from the respective model cards).
# ---------------------------------------------------------------------------

PAPER_MODELS = {
    "gpt2-xl": ModelConfig("gpt2-xl", 50257, 1600, 25, 48, 6400, 1024,
                           dtype="f16", executable=False),
    "llama3-1b": ModelConfig("llama3-1b", 128256, 2048, 32, 16, 8192, 8192,
                             dtype="bf16", executable=False),
    "llama2-7b": ModelConfig("llama2-7b", 32000, 4096, 32, 32, 11008, 4096,
                             dtype="f16", executable=False),
    "llama2-13b": ModelConfig("llama2-13b", 32000, 5120, 40, 40, 13824, 4096,
                              dtype="f16", executable=False),
    "granite-20b": ModelConfig("granite-20b", 49152, 6144, 48, 52, 24576, 8192,
                               dtype="f16", executable=False),
    "starcoder-15b": ModelConfig("starcoder-15b", 49152, 6144, 48, 40, 24576,
                                 8192, dtype="f32", executable=False,
                                 param_bytes=4),
    "gemma2-27b": ModelConfig("gemma2-27b", 256128, 4608, 32, 46, 36864, 8192,
                              dtype="bf16", executable=False),
}

EXECUTABLE_MODELS = {m.name: m for m in (SYM_TINY, SYM_SMALL)}
ALL_MODELS = {**EXECUTABLE_MODELS, **PAPER_MODELS}


# Token-count buckets for the flattened-linear executor artifacts.  HLO is
# shape-specialized, so the executor pads a cross-client flattened batch to
# the next bucket (<=2x, amortized ~1.15x) instead of per-request
# max-seq-len padding (see DESIGN.md section 4).
TOKEN_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)

# Sequence-length buckets for client-side attention artifacts.
SEQ_BUCKETS = (16, 32, 64, 128, 256, 512)

# Per-request batch sizes for attention artifacts.
ATTN_BATCHES = (1, 2, 4)

# LoRA ranks exported (paper evaluates r=8 and r=64: LoRA1..4 in Table 2).
LORA_RANKS = (8, 64)


def bucket_for(n: int, buckets=TOKEN_BUCKETS) -> int:
    """Smallest bucket >= n; raises if n exceeds the largest bucket."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"{n} exceeds largest bucket {buckets[-1]}")
