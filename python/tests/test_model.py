"""L2 model-level tests: split-composition == monolith, shapes, golden."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, container, model
from compile.kernels import ref

CFG = configs.SYM_TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


def test_forward_shapes(params):
    tokens = jnp.asarray(np.arange(16) % CFG.vocab, jnp.int32)
    logits = model.forward(CFG, params, tokens)
    assert logits.shape == (16, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_forward_deterministic(params):
    tokens = jnp.asarray([1, 2, 3, 4], jnp.int32)
    a = model.forward(CFG, params, tokens)
    b = model.forward(CFG, params, tokens)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adapter_changes_output(params):
    tokens = jnp.asarray([5, 6, 7, 8], jnp.int32)
    base = model.forward(CFG, params, tokens)
    adapted = model.forward(CFG, params, tokens, model.init_lora(CFG, 8))
    assert not np.allclose(np.asarray(base), np.asarray(adapted))


def test_split_composition_equals_monolith(params):
    """Re-compose the model from the *artifact functions* (what Rust does)
    and check it matches the monolithic reference exactly."""
    tokens = np.asarray([3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7, 9, 3],
                        np.int32)
    s = len(tokens)
    nh, hd = CFG.n_heads, CFG.d_head
    scale_unused = 1.0 / np.sqrt(hd)  # baked into the attention artifact

    h = model.art_embed(jnp.asarray(tokens), jnp.arange(s, dtype=jnp.int32),
                        params["embed"], params["pos"])[0]
    for l in range(CFG.n_layers):
        a_in = ref.rmsnorm(h, params[f"l{l}.norm1"])
        qkv = model.art_linear_fwd(a_in, params[f"l{l}.wqkv"],
                                   params[f"l{l}.bqkv"])[0]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        qh = q.reshape(s, nh, hd).transpose(1, 0, 2)
        kh = k.reshape(s, nh, hd).transpose(1, 0, 2)
        vh = v.reshape(s, nh, hd).transpose(1, 0, 2)
        from functools import partial
        attn = model.art_attn_prefill(qh, kh, vh, scale=scale_unused)[0]
        attn = attn.transpose(1, 0, 2).reshape(s, nh * hd)
        o = model.art_linear_fwd(attn, params[f"l{l}.wo"],
                                 params[f"l{l}.bo"])[0]
        h = h + o
        m_in = ref.rmsnorm(h, params[f"l{l}.norm2"])
        u = ref.gelu(model.art_linear_fwd(m_in, params[f"l{l}.wup"],
                                          params[f"l{l}.bup"])[0])
        h = h + model.art_linear_fwd(u, params[f"l{l}.wdown"],
                                     params[f"l{l}.bdown"])[0]
    hf = ref.rmsnorm(h, params["norm_f"])
    logits = model.art_linear_fwd(hf, params["lm_head_w"],
                                  params["lm_head_b"])[0]
    want = model.forward(CFG, params, jnp.asarray(tokens))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_train_step_grads_nonzero(params):
    adapter = model.init_lora(CFG, 8)
    tokens = jnp.asarray(np.arange(16) % CFG.vocab, jnp.int32)
    labels = jnp.asarray((np.arange(16) + 1) % CFG.vocab, jnp.int32)
    loss, grads = model.train_step(CFG, params, adapter, tokens, labels)
    assert np.isfinite(float(loss))
    total = sum(float(jnp.abs(g).sum()) for g in grads.values())
    assert total > 0.0


def test_training_reduces_loss(params):
    """A few Adam steps on one batch must reduce the loss — the loss-curve
    sanity behind the fine-tuning experiments."""
    adapter = model.init_lora(CFG, 8)
    tokens = jnp.asarray(np.arange(16) % CFG.vocab, jnp.int32)
    labels = jnp.asarray((np.arange(16) + 1) % CFG.vocab, jnp.int32)
    m = {k: jnp.zeros_like(v) for k, v in adapter.items()}
    v = {k: jnp.zeros_like(x) for k, x in adapter.items()}
    losses = []
    for t in range(1, 6):
        loss, grads = model.train_step(CFG, params, adapter, tokens, labels)
        losses.append(float(loss))
        for k in adapter:
            p2, m2, v2 = ref.adam_step(adapter[k].ravel(), grads[k].ravel(),
                                       m[k].ravel(), v[k].ravel(), float(t),
                                       lr=1e-2)
            adapter[k] = p2.reshape(adapter[k].shape)
            m[k] = m2.reshape(m[k].shape)
            v[k] = v2.reshape(v[k].shape)
    assert losses[-1] < losses[0]


def test_container_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a": rng.standard_normal((3, 4)).astype(np.float32),
        "b": rng.integers(0, 100, (7,)).astype(np.int32),
        "scalar": np.asarray([1.5], np.float32),
    }
    p = tmp_path / "t.bin"
    container.write_tensors(p, tensors)
    back = container.read_tensors(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])


ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.txt")),
                    reason="artifacts not built")
def test_manifest_artifacts_exist():
    with open(os.path.join(ART_DIR, "manifest.txt")) as f:
        lines = f.read().splitlines()
    assert lines[0].startswith("symbiosis-manifest")
    arts = [l.split() for l in lines if l.startswith("artifact ")]
    assert len(arts) > 150
    for parts in arts:
        assert os.path.exists(os.path.join(ART_DIR, parts[2])), parts[1]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR,
                                                    "golden_sym-tiny.bin")),
                    reason="artifacts not built")
def test_golden_matches_reference(params):
    g = container.read_tensors(os.path.join(ART_DIR, "golden_sym-tiny.bin"))
    logits = model.forward(CFG, params, jnp.asarray(g["tokens16"]))
    np.testing.assert_allclose(np.asarray(logits), g["base_logits16"],
                               rtol=1e-5, atol=1e-5)
    gen = model.generate(CFG, params, g["gen_prompt"], 8,
                         model.init_lora(CFG, 8))
    np.testing.assert_array_equal(gen, g["gen_tokens"])
