"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes per the repro plan; each Pallas kernel must
match its pure-jnp oracle in ``kernels.ref`` to fp32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, linear, lora, ref

RTOL, ATOL = 1e-4, 1e-4


def arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------------------
# linear_flat / linear_bwd_data
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 3, 8, 32, 128]),
    din=st.sampled_from([16, 64, 256]),
    dout=st.sampled_from([16, 64, 192, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_fwd_matches_ref(t, din, dout, seed):
    rng = np.random.default_rng(seed)
    x, w, b = arr(rng, t, din), arr(rng, din, dout), arr(rng, dout)
    np.testing.assert_allclose(
        linear.linear_flat(x, w, b), ref.linear_flat(x, w, b),
        rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 5, 64, 128]),
    din=st.sampled_from([16, 64, 256]),
    dout=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_bwd_matches_ref(t, din, dout, seed):
    rng = np.random.default_rng(seed)
    dy, w = arr(rng, t, dout), arr(rng, din, dout)
    np.testing.assert_allclose(
        linear.linear_bwd_data(dy, w), ref.linear_bwd_data(dy, w),
        rtol=RTOL, atol=ATOL)


def test_linear_odd_shapes_fall_back_to_divisor_blocks():
    # T=7 is prime: the block picker must clamp to 7 (or 1) and still tile.
    rng = np.random.default_rng(0)
    x, w, b = arr(rng, 7, 48), arr(rng, 48, 80), arr(rng, 80)
    np.testing.assert_allclose(
        linear.linear_flat(x, w, b), ref.linear_flat(x, w, b),
        rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([16, 32, 64, 128]),
    h=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attn_prefill_matches_ref(bh, s, h, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (arr(rng, bh, s, h) for _ in range(3))
    scale = 1.0 / np.sqrt(h)
    np.testing.assert_allclose(
        attention.attention_prefill(q, k, v, scale, bq=16, bk=16),
        ref.attention_prefill(q, k, v, scale), rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    bh=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([16, 64, 128]),
    kv_len_frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_attn_decode_masks_bucket_padding(bh, s, kv_len_frac, seed):
    rng = np.random.default_rng(seed)
    h = 16
    q = arr(rng, bh, 1, h)
    k, v = arr(rng, bh, s, h), arr(rng, bh, s, h)
    kv_len = max(1, int(s * kv_len_frac))
    scale = 1.0 / np.sqrt(h)
    got = attention.attention_decode(
        q, k, v, jnp.asarray([kv_len], jnp.int32), scale, bk=16)
    # oracle: slice off the padding entirely
    want = ref.attention_decode(q, k[:, :kv_len], v[:, :kv_len],
                                jnp.asarray([kv_len], jnp.int32), scale)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_attn_decode_padding_values_are_ignored():
    # Poison the padded region with huge values; output must not change.
    rng = np.random.default_rng(3)
    q, k, v = arr(rng, 4, 1, 16), arr(rng, 4, 64, 16), arr(rng, 4, 64, 16)
    kv_len = jnp.asarray([40], jnp.int32)
    base = attention.attention_decode(q, k, v, kv_len, 0.25, bk=16)
    k2 = k.at[:, 40:].set(1e6)
    v2 = v.at[:, 40:].set(-1e6)
    poisoned = attention.attention_decode(q, k2, v2, kv_len, 0.25, bk=16)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


def test_attn_prefill_causality():
    # Changing k/v at position j must not affect outputs at positions < j.
    rng = np.random.default_rng(4)
    q, k, v = (arr(rng, 4, 32, 16) for _ in range(3))
    base = np.asarray(attention.attention_prefill(q, k, v, 0.25, bq=16,
                                                  bk=16))
    k2 = k.at[:, 20:].add(5.0)
    v2 = v.at[:, 20:].add(-3.0)
    mod = np.asarray(attention.attention_prefill(q, k2, v2, 0.25, bq=16,
                                                 bk=16))
    np.testing.assert_allclose(base[:, :20], mod[:, :20], rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(base[:, 20:], mod[:, 20:])


def test_attn_bwd_matches_autodiff():
    rng = np.random.default_rng(5)
    q, k, v, do = (arr(rng, 4, 32, 16) for _ in range(4))
    got = ref.attention_bwd(q, k, v, do, 0.25)
    import jax
    _, vjp = jax.vjp(
        lambda a, b, c: ref.attention_prefill(a, b, c, 0.25), q, k, v)
    want = vjp(do)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# LoRA
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 8, 64, 128]),
    d=st.sampled_from([16, 64]),
    r=st.sampled_from([4, 8, 64]),
    scale=st.floats(0.25, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_lora_fwd_matches_ref(t, d, r, scale, seed):
    rng = np.random.default_rng(seed)
    x, a, b = arr(rng, t, d), arr(rng, d, r), arr(rng, r, d)
    np.testing.assert_allclose(
        lora.lora_apply(x, a, b, scale), ref.lora_apply(x, a, b, scale),
        rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 8, 64]),
    r=st.sampled_from([4, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lora_bwd_matches_ref(t, r, seed):
    rng = np.random.default_rng(seed)
    d = 64
    x, dy = arr(rng, t, d), arr(rng, t, d)
    a, b = arr(rng, d, r), arr(rng, r, d)
    got = lora.lora_bwd(x, dy, a, b, 2.0)
    want = ref.lora_bwd(x, dy, a, b, 2.0)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=RTOL, atol=ATOL)


def test_lora_bwd_matches_autodiff():
    import jax
    rng = np.random.default_rng(6)
    x, a, b = arr(rng, 16, 64), arr(rng, 64, 8), arr(rng, 8, 64)
    dy = arr(rng, 16, 64)
    _, vjp = jax.vjp(lambda x_, a_, b_: ref.lora_apply(x_, a_, b_, 2.0),
                     x, a, b)
    dx_w, da_w, db_w = vjp(dy)
    da, db, dx = ref.lora_bwd(x, dy, a, b, 2.0)
    np.testing.assert_allclose(da, da_w, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(db, db_w, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(dx, dx_w, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# loss / adam oracles self-check vs autodiff
# ---------------------------------------------------------------------------

def test_xent_grad_matches_autodiff():
    import jax
    rng = np.random.default_rng(8)
    logits = arr(rng, 12, 32)
    labels = jnp.asarray(rng.integers(0, 32, 12), jnp.int32)
    w = jnp.ones(12, jnp.float32)
    loss, dlogits = ref.softmax_xent(logits, labels, w)
    want = jax.grad(
        lambda lg: ref.softmax_xent(lg, labels, w)[0])(logits)
    np.testing.assert_allclose(dlogits, want, rtol=RTOL, atol=ATOL)


def test_xent_padding_weights_are_exact():
    rng = np.random.default_rng(9)
    logits = arr(rng, 16, 32)
    labels = jnp.asarray(rng.integers(0, 32, 16), jnp.int32)
    w = jnp.asarray([1.0] * 10 + [0.0] * 6, jnp.float32)
    loss_p, dl_p = ref.softmax_xent(logits, labels, w)
    loss_s, dl_s = ref.softmax_xent(logits[:10], labels[:10])
    np.testing.assert_allclose(loss_p, loss_s, rtol=1e-6)
    np.testing.assert_allclose(dl_p[:10], dl_s, rtol=1e-5, atol=1e-6)
    assert np.all(np.asarray(dl_p[10:]) == 0.0)


def test_adam_reduces_loss_direction():
    p = jnp.asarray([1.0, -2.0, 3.0])
    g = jnp.asarray([0.5, -0.5, 0.1])
    p2, m, v = ref.adam_step(p, g, jnp.zeros(3), jnp.zeros(3), 1.0)
    # step direction opposes gradient sign
    assert np.all(np.sign(np.asarray(p - p2)) == np.sign(np.asarray(g)))
